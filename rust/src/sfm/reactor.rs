//! The event-driven fleet core: a **shard pool** of reactor threads
//! owns every registered connection, so resident thread count is
//! O(cores + active jobs) instead of O(clients).
//!
//! Before this module, each fleet connection cost a dedicated receive
//! pump thread (blocking `Driver::recv`) plus a heartbeat thread — 512
//! simulated clients passed, 10 000 could not even be spawned. PR 6
//! inverted that model with a single reactor thread; this revision
//! shards it across cores so one poll loop is no longer the ceiling:
//!
//! ```text
//!                          ┌────────────────────────────┐
//!    TcpStream (nonblock) ─┤  sfm-reactor/0             │──▶ MuxSink
//!    inproc rx + ReadyHook─┤  poll set + timer wheel    │──▶ MuxSink
//!                          ├────────────────────────────┤
//!    TcpListener (accept) ─┤  sfm-reactor/1             │──▶ AcceptFn
//!    TcpStream (nonblock) ─┤  poll set + timer wheel    │──▶ MuxSink
//!                          ├────────────────────────────┤
//!                          │  ... (default min(cores,8),│
//!                          │  FEDFLARE_REACTOR_SHARDS)  │
//!                          └────────────────────────────┘
//! ```
//!
//! * **Sharding**: each shard owns its own poll set, partial-frame
//!   buffers, ready queue, and timer wheel. A connection is pinned to
//!   the least-loaded shard at registration and its shard index is
//!   packed into the high bits of its [`Token`], so every frame, resume
//!   timer, and close of that connection runs on one thread — ordering
//!   and priority-lane guarantees are exactly the single-reactor
//!   semantics, scaled out. With `FEDFLARE_REACTOR_SHARDS=1` the pool
//!   degenerates to PR 6's single thread, byte for byte.
//! * **TCP** connections are switched to non-blocking mode and polled;
//!   incoming bytes accumulate in a per-connection partial buffer and
//!   complete `u32 len | frame` records are decoded incrementally. A
//!   connection deregistered mid-frame drops its partial bytes into
//!   [`mem::track_evicted`] — never leaked, never delivered torn.
//! * **Listeners** ride the same poll sets: [`Reactor::register_listener`]
//!   parks a non-blocking `TcpListener` on a shard and invokes an
//!   [`AcceptFn`] per accepted socket (bounded per round so an accept
//!   storm cannot starve established connections). No blocking accept
//!   thread, no per-handshake read timeout — see `sfm::accept`.
//! * **In-process** connections ride the loop through a [`ReadyHook`]:
//!   the sending side pokes the owning shard after each channel push
//!   (the shard index travels inside the token), so inproc delivery
//!   stays event-driven, with a slow probe sweep catching peer-drop
//!   disconnects.
//! * **Timers** (heartbeat sends, throttle resume deadlines, the fleet
//!   suspect/gone sweep) live on the wheel of the shard that owns the
//!   connection; free-standing intervals round-robin across shards.
//!
//! Frames are handed to a [`FrameSink`] (the mux's routing/priority
//! logic). The sink always takes ownership of the frame — when receive
//! throttling has no budget the sink *parks* data frames internally and
//! answers with [`SinkStatus::Resume`], so reactor threads never block
//! in a token bucket. Control frames (heartbeats, FIN, job 0) bypass
//! parking entirely — the priority lane that keeps a heartbeat from
//! queueing behind a multi-megabyte tensor transfer.
//!
//! Each shard exports load counters ([`Reactor::shard_stats`]): resident
//! connections, ready-queue depth, frames/bytes ingested, and loop
//! saturation (busy vs idle time) — the signals `bench_fleet` records as
//! per-shard balance and `metrics` can sample per round.
//!
//! This is the only module under `rust/src/sfm/` and `rust/src/fleet/`
//! allowed to spawn threads, and only at the single marked shard-pool
//! site in [`global`] (CI enforces it; see
//! `scripts/check_no_thread_spawn.sh`). Driver stacks that cannot
//! express readiness use [`spawn_poll_pump`] — a timer-wheel poll task,
//! not a thread.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet};
use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use super::{Driver, Frame, SfmError};
use crate::obs;
use crate::util::mem;

/// Identifies one registered connection. The owning shard's index is
/// packed into the high bits (see [`shard_index`]).
pub type Token = u64;
/// Identifies one interval task on a shard's timer wheel (shard index
/// in the high bits, like [`Token`]).
pub type TimerId = u64;
/// An interval task: runs every period on its shard's thread; return
/// `false` to cancel.
pub type IntervalFn = Box<dyn FnMut() -> bool + Send>;
/// Callback for each socket accepted by a registered listener. Runs on
/// the listener's shard; may call back into the reactor (e.g.
/// [`Reactor::register`]) — no shard lock is held during the call.
pub type AcceptFn = Box<dyn FnMut(TcpStream, SocketAddr) + Send>;

/// Poll cadence for non-blocking TCP sockets (no epoll in the offline
/// crate set, so readiness is sampled; each sample drains everything
/// available, bounding per-connection throughput at MB/ms scale).
const TCP_POLL: Duration = Duration::from_millis(1);
/// Probe cadence for in-process queues: normally event-driven via
/// [`ReadyHook`], this sweep only exists to notice peers that dropped
/// their sender without a final frame.
const QUEUE_PROBE: Duration = Duration::from_millis(250);
/// Per-connection read budget per service round, so one firehose
/// connection cannot starve the rest of the loop.
const MAX_READ_PER_ROUND: usize = 1 << 20;
/// Accepts per listener per service round, so an accept storm cannot
/// starve established connections on the same shard.
const MAX_ACCEPT_PER_ROUND: usize = 256;
/// Poll cadence for [`spawn_poll_pump`] fallback drains.
const POLL_PUMP_PERIOD: Duration = Duration::from_millis(1);

/// Shard index lives in the top bits of every token / timer id.
const SHARD_SHIFT: u32 = 48;
/// Without `FEDFLARE_REACTOR_SHARDS`, the pool defaults to
/// `min(available_parallelism, MAX_DEFAULT_SHARDS)`.
const MAX_DEFAULT_SHARDS: usize = 8;

/// The shard that owns `id` (a [`Token`] or [`TimerId`]).
pub fn shard_index(id: u64) -> usize {
    (id >> SHARD_SHIFT) as usize
}

/// How a receive endpoint plugs into the reactor (see
/// [`Driver::registration`]).
pub enum Registration {
    /// A TCP socket, switched to non-blocking and polled. NOTE: the
    /// socket's send half (a `try_clone` sharing the same file
    /// description) becomes non-blocking too — [`super::tcp::TcpDriver`]'s
    /// send path retries `WouldBlock` to preserve blocking semantics for
    /// its callers.
    Tcp { stream: TcpStream, verify_crc: bool },
    /// An in-process frame queue plus the hook its sender pokes.
    Queue {
        rx: Arc<Mutex<Receiver<Frame>>>,
        hook: ReadyHook,
    },
}

/// Shared between an in-process sender and the reactor: once the peer's
/// receive half is registered, every send pokes the owning shard awake
/// (the shard rides inside the bound token).
#[derive(Clone, Default)]
pub struct ReadyHook {
    token: Arc<Mutex<Option<Token>>>,
}

impl ReadyHook {
    /// Called by the sending side after pushing a frame.
    pub fn notify(&self) {
        let tok = *self.token.lock().unwrap();
        if let Some(tok) = tok {
            global().mark_ready(tok);
        }
    }

    fn bind(&self, tok: Token) {
        *self.token.lock().unwrap() = Some(tok);
    }
}

/// Verdict a [`FrameSink`] returns to the reactor.
pub enum SinkStatus {
    /// Keep feeding frames as they arrive.
    Ready,
    /// The sink parked work it could not admit yet (throttle budget):
    /// call [`FrameSink::on_resume`] at `at`. If `pause_reads` the sink's
    /// parking buffer is full — stop reading the transport until then
    /// (kernel/window backpressure takes over).
    Resume { at: Instant, pause_reads: bool },
    /// Deregister the connection.
    Closed,
}

/// Where decoded frames go. Implemented by the mux's routing logic; the
/// sink always takes ownership of the frame (parking it internally if
/// throttled), so the reactor never has to un-read anything.
pub trait FrameSink: Send {
    /// A complete frame arrived.
    fn on_frame(&mut self, frame: Frame) -> SinkStatus;
    /// A previously returned `Resume` deadline elapsed.
    fn on_resume(&mut self) -> SinkStatus;
    /// The transport died; the reactor deregisters after this call.
    fn on_closed(&mut self, err: SfmError);
}

enum Source {
    Tcp(TcpSource),
    Queue { rx: Arc<Mutex<Receiver<Frame>>> },
    Listener {
        listener: TcpListener,
        on_accept: AcceptFn,
    },
}

struct TcpSource {
    stream: TcpStream,
    verify_crc: bool,
    /// Partial-frame accumulation buffer.
    buf: Vec<u8>,
}

impl Drop for TcpSource {
    fn drop(&mut self) {
        // Killed / closed mid-frame: the half-decoded bytes are evicted,
        // not leaked and never delivered torn.
        if !self.buf.is_empty() {
            mem::track_evicted(self.buf.len());
        }
    }
}

/// Sink for listener slots: a listener produces sockets via its
/// [`AcceptFn`], never frames.
struct NullSink;

impl FrameSink for NullSink {
    fn on_frame(&mut self, _frame: Frame) -> SinkStatus {
        SinkStatus::Ready
    }
    fn on_resume(&mut self) -> SinkStatus {
        SinkStatus::Ready
    }
    fn on_closed(&mut self, _err: SfmError) {}
}

struct Conn {
    source: Source,
    sink: Box<dyn FrameSink>,
    reads_paused: bool,
    /// A Resume timer is already queued for this connection.
    resume_pending: bool,
    closed: bool,
}

struct ConnSlot {
    conn: Arc<Mutex<Conn>>,
    /// Polled every TCP round (true for sockets *and* listeners).
    is_tcp: bool,
}

enum TimerKind {
    Resume(Token),
    Interval(TimerId),
}

struct TimerEntry {
    at: Instant,
    seq: u64,
    kind: TimerKind,
}

impl PartialEq for TimerEntry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for TimerEntry {}
impl PartialOrd for TimerEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for TimerEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

struct IntervalTask {
    period: Duration,
    /// Taken out while running (outside the shard lock).
    f: Option<IntervalFn>,
}

#[derive(Default)]
struct Inner {
    conns: HashMap<Token, ConnSlot>,
    ready: HashSet<Token>,
    timers: BinaryHeap<Reverse<TimerEntry>>,
    intervals: HashMap<TimerId, IntervalTask>,
    next_token: u64,
    next_id: u64,
    tcp_conns: usize,
}

impl Inner {
    fn push_timer(&mut self, at: Instant, kind: TimerKind) {
        let seq = self.next_id;
        self.next_id += 1;
        self.timers.push(Reverse(TimerEntry { at, seq, kind }));
    }
}

/// One reactor shard: its own poll set, ready queue, and timer wheel,
/// plus lock-free load counters for balance metrics. The counters are
/// `&'static` handles into the [`obs`] registry (labeled `{shard=i}`),
/// so shard load shows up in every registry snapshot — `shard_stats`
/// reads the same handles, keeping the two views one surface.
struct Shard {
    idx: usize,
    inner: Mutex<Inner>,
    cv: Condvar,
    /// Resident connections (including listeners) — the least-loaded
    /// pinning signal, readable without the shard lock
    /// (`reactor.conns{shard=i}`).
    conn_count: &'static obs::Gauge,
    /// `reactor.frames_in{shard=i}`.
    frames_in: &'static obs::Counter,
    /// `reactor.bytes_in{shard=i}`.
    bytes_in: &'static obs::Counter,
    /// Nanoseconds spent doing work, outside the condvar wait
    /// (`reactor.busy_ns{shard=i}`).
    busy_ns: &'static obs::Counter,
    /// Nanoseconds spent parked in the condvar wait
    /// (`reactor.idle_ns{shard=i}`).
    idle_ns: &'static obs::Counter,
}

/// A point-in-time load snapshot of one shard (see
/// [`Reactor::shard_stats`]).
#[derive(Debug, Clone)]
pub struct ShardStats {
    pub shard: usize,
    /// Resident connections, listeners included.
    pub conns: usize,
    /// Polled (TCP + listener) connections.
    pub tcp_conns: usize,
    /// Ready-queue depth at sample time.
    pub queue_depth: usize,
    /// Pending timer-wheel entries.
    pub timers: usize,
    /// Live interval tasks.
    pub intervals: usize,
    /// Cumulative frames ingested by this shard.
    pub frames_in: u64,
    /// Cumulative payload/wire bytes ingested by this shard.
    pub bytes_in: u64,
    /// Cumulative ns spent servicing (outside the condvar wait).
    pub busy_ns: u64,
    /// Cumulative ns parked in the condvar wait.
    pub idle_ns: u64,
}

impl ShardStats {
    /// Fraction of loop time spent busy, 0.0..=1.0 (loop saturation).
    pub fn saturation(&self) -> f64 {
        let total = self.busy_ns.saturating_add(self.idle_ns);
        if total == 0 {
            0.0
        } else {
            self.busy_ns as f64 / total as f64
        }
    }
}

/// The process-wide reactor: a pool of shards, each a thread started on
/// first use. All public methods route by the shard index packed into
/// the token / timer id, so callers keep the single-reactor API.
pub struct Reactor {
    shards: Vec<Shard>,
    /// Round-robin cursor for free-standing intervals.
    rr: AtomicUsize,
}

fn configured_shards() -> usize {
    if let Ok(v) = std::env::var("FEDFLARE_REACTOR_SHARDS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(MAX_DEFAULT_SHARDS)
}

/// The process-wide reactor instance. Shard count is latched on first
/// use: `FEDFLARE_REACTOR_SHARDS` if set, else
/// `min(available_parallelism, 8)`.
pub fn global() -> &'static Reactor {
    static GLOBAL: OnceLock<&'static Reactor> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let n = configured_shards();
        let shards = (0..n)
            .map(|idx| {
                let label = idx.to_string();
                let l: &[(&str, &str)] = &[("shard", &label)];
                Shard {
                    idx,
                    inner: Mutex::new(Inner::default()),
                    cv: Condvar::new(),
                    conn_count: obs::gauge_with("reactor.conns", l),
                    frames_in: obs::counter_with("reactor.frames_in", l),
                    bytes_in: obs::counter_with("reactor.bytes_in", l),
                    busy_ns: obs::counter_with("reactor.busy_ns", l),
                    idle_ns: obs::counter_with("reactor.idle_ns", l),
                }
            })
            .collect();
        let r: &'static Reactor = Box::leak(Box::new(Reactor {
            shards,
            rr: AtomicUsize::new(0),
        }));
        for shard in &r.shards {
            // threadlint-allow: shard-pool
            std::thread::Builder::new()
                .name(format!("sfm-reactor/{}", shard.idx))
                .stack_size(512 << 10)
                .spawn(move || shard.run_loop())
                .expect("spawn sfm-reactor shard");
        }
        r
    })
}

impl Reactor {
    /// Number of shards in the pool.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard owning `id` (defensive clamp for garbage ids).
    fn shard_of(&self, id: u64) -> &Shard {
        let idx = shard_index(id).min(self.shards.len() - 1);
        &self.shards[idx]
    }

    fn least_loaded(&self) -> &Shard {
        self.shards
            .iter()
            .min_by_key(|s| s.conn_count.get())
            .expect("reactor has at least one shard")
    }

    /// Register a connection; frames flow into `sink` from now on.
    pub fn register(&self, reg: Registration, sink: Box<dyn FrameSink>) -> Token {
        self.register_with(reg, move |_| sink)
    }

    /// Register a connection whose sink needs to know its own token
    /// (e.g. to deregister itself later): `make` runs after the token is
    /// minted but before any frame is serviced, with no shard lock held.
    pub fn register_with(
        &self,
        reg: Registration,
        make: impl FnOnce(Token) -> Box<dyn FrameSink>,
    ) -> Token {
        let shard = self.least_loaded();
        let token = shard.mint_token();
        let sink = make(token);
        let (source, hook, is_tcp) = match reg {
            Registration::Tcp { stream, verify_crc } => {
                let _ = stream.set_nonblocking(true);
                (
                    Source::Tcp(TcpSource {
                        stream,
                        verify_crc,
                        buf: Vec::new(),
                    }),
                    None,
                    true,
                )
            }
            Registration::Queue { rx, hook } => (Source::Queue { rx }, Some(hook), false),
        };
        shard.install(token, source, sink, is_tcp);
        // Bind outside the shard lock (hook lock then shard lock is the
        // sender's order; never nest the other way).
        if let Some(hook) = hook {
            hook.bind(token);
        }
        // Frames may predate registration (or the bind above): service once.
        self.mark_ready(token);
        token
    }

    /// Park a non-blocking listener on a shard: `on_accept` runs on that
    /// shard for every accepted socket (at most [`MAX_ACCEPT_PER_ROUND`]
    /// per poll round). Deregister the returned token to stop accepting.
    pub fn register_listener(
        &self,
        listener: TcpListener,
        on_accept: AcceptFn,
    ) -> std::io::Result<Token> {
        listener.set_nonblocking(true)?;
        let shard = self.least_loaded();
        let token = shard.mint_token();
        shard.install(
            token,
            Source::Listener { listener, on_accept },
            Box::new(NullSink),
            true,
        );
        Ok(token)
    }

    /// Remove a connection. The sink is dropped without `on_closed`; a
    /// TCP partial-frame buffer is accounted as evicted.
    pub fn deregister(&self, token: Token) {
        self.shard_of(token).deregister_local(token);
    }

    /// Wake the owning shard: `token` has frames queued.
    pub fn mark_ready(&self, token: Token) {
        let shard = self.shard_of(token);
        let mut inner = shard.inner.lock().unwrap();
        if inner.conns.contains_key(&token) {
            inner.ready.insert(token);
            shard.cv.notify_all();
        }
    }

    /// Run `f` every `period` on a reactor shard until it returns
    /// `false` (or [`Reactor::cancel_interval`]). First run after one
    /// period. Free-standing intervals round-robin across shards.
    pub fn add_interval(&self, period: Duration, f: IntervalFn) -> TimerId {
        let idx = self.rr.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        let shard = &self.shards[idx];
        let mut inner = shard.inner.lock().unwrap();
        let id = ((shard.idx as u64) << SHARD_SHIFT) | inner.next_id;
        inner.next_id += 1;
        inner.intervals.insert(id, IntervalTask { period, f: Some(f) });
        inner.push_timer(Instant::now() + period, TimerKind::Interval(id));
        shard.cv.notify_all();
        id
    }

    /// Cancel an interval task (no-op if already finished).
    pub fn cancel_interval(&self, id: TimerId) {
        self.shard_of(id).inner.lock().unwrap().intervals.remove(&id);
    }

    /// Per-shard load snapshot: connection counts, queue depths,
    /// ingest counters, and loop saturation.
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.shards
            .iter()
            .map(|s| {
                let inner = s.inner.lock().unwrap();
                ShardStats {
                    shard: s.idx,
                    conns: inner.conns.len(),
                    tcp_conns: inner.tcp_conns,
                    queue_depth: inner.ready.len(),
                    timers: inner.timers.len(),
                    intervals: inner.intervals.len(),
                    frames_in: s.frames_in.get(),
                    bytes_in: s.bytes_in.get(),
                    busy_ns: s.busy_ns.get(),
                    idle_ns: s.idle_ns.get(),
                }
            })
            .collect()
    }
}

impl Shard {
    fn mint_token(&self) -> Token {
        let mut inner = self.inner.lock().unwrap();
        let token = ((self.idx as u64) << SHARD_SHIFT) | inner.next_token;
        inner.next_token += 1;
        token
    }

    fn install(&self, token: Token, source: Source, sink: Box<dyn FrameSink>, is_tcp: bool) {
        let mut inner = self.inner.lock().unwrap();
        if is_tcp {
            inner.tcp_conns += 1;
        }
        inner.conns.insert(
            token,
            ConnSlot {
                conn: Arc::new(Mutex::new(Conn {
                    source,
                    sink,
                    reads_paused: false,
                    resume_pending: false,
                    closed: false,
                })),
                is_tcp,
            },
        );
        drop(inner);
        self.conn_count.add(1);
        self.cv.notify_all();
    }

    fn deregister_local(&self, token: Token) {
        let slot = {
            let mut inner = self.inner.lock().unwrap();
            let slot = inner.conns.remove(&token);
            inner.ready.remove(&token);
            if slot.as_ref().is_some_and(|s| s.is_tcp) {
                inner.tcp_conns -= 1;
            }
            slot
        };
        if slot.is_some() {
            self.conn_count.sub(1);
        }
        // Drop outside the lock: TcpSource::drop tracks torn-frame bytes
        // and the sink's drop may run arbitrary (mux) code.
        drop(slot);
    }

    // ------------------------------------------------------------ loop

    fn run_loop(&self) {
        let mut last_probe = Instant::now();
        loop {
            let loop_start = Instant::now();
            let mut resumes: Vec<(Token, Arc<Mutex<Conn>>)> = Vec::new();
            let mut intervals: Vec<(TimerId, IntervalFn, Duration)> = Vec::new();
            let mut service: Vec<(Token, Arc<Mutex<Conn>>)> = Vec::new();
            {
                let mut inner = self.inner.lock().unwrap();
                let now = Instant::now();
                while let Some(Reverse(top)) = inner.timers.peek() {
                    if top.at > now {
                        break;
                    }
                    let Reverse(entry) = inner.timers.pop().unwrap();
                    match entry.kind {
                        TimerKind::Resume(tok) => {
                            if let Some(slot) = inner.conns.get(&tok) {
                                resumes.push((tok, slot.conn.clone()));
                            }
                        }
                        TimerKind::Interval(id) => {
                            if let Some(task) = inner.intervals.get_mut(&id) {
                                if let Some(f) = task.f.take() {
                                    intervals.push((id, f, task.period));
                                }
                            }
                        }
                    }
                }
                let probe = now.duration_since(last_probe) >= QUEUE_PROBE;
                if probe {
                    last_probe = now;
                }
                let ready: HashSet<Token> = inner.ready.drain().collect();
                for tok in &ready {
                    if let Some(slot) = inner.conns.get(tok) {
                        service.push((*tok, slot.conn.clone()));
                    }
                }
                if inner.tcp_conns > 0 || probe {
                    for (tok, slot) in inner.conns.iter() {
                        if (slot.is_tcp || probe) && !ready.contains(tok) {
                            service.push((*tok, slot.conn.clone()));
                        }
                    }
                }
            }

            for (tok, conn) in &resumes {
                self.service(*tok, conn, true);
            }
            for (tok, conn) in &service {
                self.service(*tok, conn, false);
            }
            for (id, mut f, period) in intervals {
                let keep = f();
                let mut inner = self.inner.lock().unwrap();
                if !keep {
                    inner.intervals.remove(&id);
                    continue;
                }
                // put the closure back unless it was cancelled mid-run
                if let Some(task) = inner.intervals.get_mut(&id) {
                    task.f = Some(f);
                    inner.push_timer(Instant::now() + period, TimerKind::Interval(id));
                }
            }

            let inner = self.inner.lock().unwrap();
            self.busy_ns.add(loop_start.elapsed().as_nanos() as u64);
            if !inner.ready.is_empty() {
                continue;
            }
            let now = Instant::now();
            let mut wait = if inner.tcp_conns > 0 { TCP_POLL } else { QUEUE_PROBE };
            if let Some(Reverse(top)) = inner.timers.peek() {
                wait = wait.min(top.at.saturating_duration_since(now));
            }
            if wait.is_zero() {
                continue;
            }
            let park = Instant::now();
            let _ = self.cv.wait_timeout(inner, wait);
            self.idle_ns.add(park.elapsed().as_nanos() as u64);
        }
    }

    /// Drain one connection's source into its sink.
    fn service(&self, token: Token, conn: &Mutex<Conn>, resume: bool) {
        let mut c = conn.lock().unwrap();
        if c.closed {
            return;
        }
        if resume {
            c.resume_pending = false;
            c.reads_paused = false;
            let status = c.sink.on_resume();
            if !self.apply(&mut c, token, status) && (c.closed || c.reads_paused) {
                return;
            }
        }
        if c.reads_paused {
            return;
        }
        let rx = match &c.source {
            Source::Queue { rx } => Some(rx.clone()),
            Source::Tcp(_) => None,
            Source::Listener { .. } => {
                self.service_listener(&mut c);
                return;
            }
        };
        match rx {
            Some(rx) => loop {
                if c.closed || c.reads_paused {
                    return;
                }
                let polled = rx.lock().unwrap().try_recv();
                match polled {
                    Ok(frame) => {
                        self.frames_in.inc();
                        self.bytes_in.add(frame.payload.len() as u64);
                        let status = c.sink.on_frame(frame);
                        self.apply(&mut c, token, status);
                    }
                    Err(TryRecvError::Empty) => return,
                    Err(TryRecvError::Disconnected) => {
                        self.close_conn(&mut c, token, SfmError::Closed);
                        return;
                    }
                }
            },
            None => self.service_tcp(&mut c, token),
        }
    }

    /// Accept up to [`MAX_ACCEPT_PER_ROUND`] sockets; the callback may
    /// re-enter the reactor (no shard lock is held here).
    fn service_listener(&self, c: &mut Conn) {
        use std::io::ErrorKind;
        for _ in 0..MAX_ACCEPT_PER_ROUND {
            let Source::Listener { listener, on_accept } = &mut c.source else {
                return;
            };
            match listener.accept() {
                Ok((stream, peer)) => on_accept(stream, peer),
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    // Transient (EMFILE under fd pressure, aborted
                    // handshake): keep the listener, retry next round.
                    obs::log!(warn, "listener accept error: {e}");
                    return;
                }
            }
        }
    }

    fn service_tcp(&self, c: &mut Conn, token: Token) {
        loop {
            if c.closed || c.reads_paused {
                return;
            }
            // 1) pull bytes + slice complete frames, borrowing the source
            let (frames, read_n, fail) = {
                let Source::Tcp(src) = &mut c.source else {
                    return;
                };
                read_and_decode(src)
            };
            self.frames_in.add(frames.len() as u64);
            self.bytes_in.add(read_n as u64);
            // 2) feed decoded frames (the sink owns them even if it
            //    answers with backpressure mid-batch)
            for frame in frames {
                let status = c.sink.on_frame(frame);
                self.apply(c, token, status);
                if c.closed {
                    return;
                }
            }
            if let Some(err) = fail {
                self.close_conn(c, token, err);
                return;
            }
            if read_n < MAX_READ_PER_ROUND {
                return; // drained (WouldBlock); next poll round continues
            }
        }
    }

    /// Apply a sink verdict; `true` = keep feeding. Resume timers land
    /// on this shard's own wheel, preserving per-connection ordering.
    fn apply(&self, c: &mut Conn, token: Token, status: SinkStatus) -> bool {
        match status {
            SinkStatus::Ready => true,
            SinkStatus::Resume { at, pause_reads } => {
                if pause_reads {
                    c.reads_paused = true;
                }
                if !c.resume_pending {
                    c.resume_pending = true;
                    let mut inner = self.inner.lock().unwrap();
                    inner.push_timer(at, TimerKind::Resume(token));
                }
                !pause_reads
            }
            SinkStatus::Closed => {
                c.closed = true;
                self.deregister_local(token);
                false
            }
        }
    }

    fn close_conn(&self, c: &mut Conn, token: Token, err: SfmError) {
        c.closed = true;
        c.sink.on_closed(err);
        self.deregister_local(token);
    }
}

/// Read available bytes (non-blocking) and slice out complete frames.
/// Returns `(frames, bytes_read, fatal_error)`.
fn read_and_decode(src: &mut TcpSource) -> (Vec<Frame>, usize, Option<SfmError>) {
    use std::io::ErrorKind;
    let mut tmp = [0u8; 16 << 10];
    let mut read_n = 0;
    let mut fail = None;
    loop {
        match src.stream.read(&mut tmp) {
            Ok(0) => {
                fail = Some(SfmError::Closed);
                break;
            }
            Ok(n) => {
                src.buf.extend_from_slice(&tmp[..n]);
                read_n += n;
                if read_n >= MAX_READ_PER_ROUND {
                    break;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::ConnectionReset
                        | ErrorKind::ConnectionAborted
                        | ErrorKind::UnexpectedEof
                ) =>
            {
                fail = Some(SfmError::Closed);
                break;
            }
            Err(e) => {
                fail = Some(SfmError::Io(e));
                break;
            }
        }
    }
    let mut frames = Vec::new();
    let mut off = 0;
    while src.buf.len().saturating_sub(off) >= 4 {
        let len =
            u32::from_le_bytes([src.buf[off], src.buf[off + 1], src.buf[off + 2], src.buf[off + 3]])
                as usize;
        if len > (1 << 30) {
            fail = Some(SfmError::Decode(format!("implausible frame length {len}")));
            break;
        }
        if src.buf.len() - off - 4 < len {
            break; // partial frame: wait for more bytes
        }
        match Frame::decode(&src.buf[off + 4..off + 4 + len], src.verify_crc) {
            Ok(f) => frames.push(f),
            Err(e) => {
                // a poisoned stream cannot be resynchronized: sever
                fail = Some(e);
                break;
            }
        }
        off += 4 + len;
    }
    src.buf.drain(..off);
    (frames, read_n, fail)
}

/// Fallback for driver stacks without a [`Driver::registration`]: a
/// timer-wheel poll task (no thread) that drains [`Driver::try_recv`]
/// every millisecond and honors the same park/resume protocol as a
/// registered connection. The driver must provide a genuinely
/// non-blocking `try_recv`; the repo's decorator stacks all do. Nothing
/// in the standard paths uses this — registration is the fast path.
pub fn spawn_poll_pump(driver: Box<dyn Driver>, sink: Box<dyn FrameSink>) {
    struct Pump {
        driver: Box<dyn Driver>,
        sink: Box<dyn FrameSink>,
        resume_at: Option<Instant>,
        reads_paused: bool,
        done: bool,
    }

    impl Pump {
        /// `true` = keep feeding this tick.
        fn apply(&mut self, status: SinkStatus) -> bool {
            match status {
                SinkStatus::Ready => {
                    self.resume_at = None;
                    self.reads_paused = false;
                    true
                }
                SinkStatus::Resume { at, pause_reads } => {
                    self.resume_at = Some(at);
                    self.reads_paused = pause_reads;
                    !pause_reads
                }
                SinkStatus::Closed => {
                    self.done = true;
                    false
                }
            }
        }

        /// Interval body; `false` cancels the task.
        fn tick(&mut self) -> bool {
            if self.done {
                return false;
            }
            if let Some(at) = self.resume_at {
                if Instant::now() < at {
                    if self.reads_paused {
                        return true; // parked: wait for the deadline
                    }
                } else {
                    self.resume_at = None;
                    self.reads_paused = false;
                    let status = self.sink.on_resume();
                    if !self.apply(status) {
                        return !self.done;
                    }
                }
            }
            if self.reads_paused {
                return true;
            }
            loop {
                match self.driver.try_recv() {
                    Ok(Some(frame)) => {
                        let status = self.sink.on_frame(frame);
                        if !self.apply(status) {
                            return !self.done;
                        }
                    }
                    Ok(None) => return true,
                    Err(err) => {
                        self.sink.on_closed(err);
                        return false;
                    }
                }
            }
        }
    }

    let mut pump = Pump {
        driver,
        sink,
        resume_at: None,
        reads_paused: false,
        done: false,
    };
    global().add_interval(POLL_PUMP_PERIOD, Box::new(move || pump.tick()));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sfm::{inproc, FLAG_FIRST, FLAG_LAST};
    use std::io::Write;
    use std::sync::atomic::{AtomicBool, Ordering};

    struct CollectSink {
        got: Arc<Mutex<Vec<Frame>>>,
        closed: Arc<AtomicBool>,
    }

    impl FrameSink for CollectSink {
        fn on_frame(&mut self, frame: Frame) -> SinkStatus {
            self.got.lock().unwrap().push(frame);
            SinkStatus::Ready
        }
        fn on_resume(&mut self) -> SinkStatus {
            SinkStatus::Ready
        }
        fn on_closed(&mut self, _err: SfmError) {
            self.closed.store(true, Ordering::SeqCst);
        }
    }

    fn frame(seq: u32, payload: Vec<u8>) -> Frame {
        Frame {
            flags: FLAG_FIRST | FLAG_LAST,
            kind: 7,
            job: 0,
            stream: 1,
            seq,
            total: 1,
            payload: payload.into(),
        }
    }

    fn wait_until(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
        let t0 = Instant::now();
        while t0.elapsed() < timeout {
            if cond() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        cond()
    }

    #[test]
    fn inproc_queue_rides_the_reactor() {
        let (mut a, b) = inproc::pair(16, "reactor-q");
        let got = Arc::new(Mutex::new(Vec::new()));
        let closed = Arc::new(AtomicBool::new(false));
        let mut recv = b.recv_half();
        let reg = recv.registration().expect("inproc recv half registers");
        let tok = global().register(
            reg,
            Box::new(CollectSink {
                got: got.clone(),
                closed: closed.clone(),
            }),
        );
        for i in 0..5 {
            a.send(frame(i, vec![i as u8; 64])).unwrap();
        }
        assert!(
            wait_until(Duration::from_secs(2), || got.lock().unwrap().len() == 5),
            "frames not delivered: {}",
            got.lock().unwrap().len()
        );
        // peer drop is noticed by the probe sweep
        drop(a);
        drop(b);
        assert!(wait_until(Duration::from_secs(2), || closed
            .load(Ordering::SeqCst)));
        global().deregister(tok); // idempotent after close
    }

    #[test]
    fn tcp_conn_decodes_incrementally() {
        let listener = crate::sfm::tcp::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = std::net::TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        let got = Arc::new(Mutex::new(Vec::new()));
        let closed = Arc::new(AtomicBool::new(false));
        let tok = global().register(
            Registration::Tcp {
                stream,
                verify_crc: true,
            },
            Box::new(CollectSink {
                got: got.clone(),
                closed: closed.clone(),
            }),
        );
        // send one frame in two halves with a pause in between
        let f = frame(0, vec![9u8; 300]);
        let bytes = f.encode();
        let mut wire = (bytes.len() as u32).to_le_bytes().to_vec();
        wire.extend_from_slice(&bytes);
        let mid = wire.len() / 2;
        client.write_all(&wire[..mid]).unwrap();
        client.flush().unwrap();
        std::thread::sleep(Duration::from_millis(30));
        assert!(got.lock().unwrap().is_empty(), "torn frame delivered");
        client.write_all(&wire[mid..]).unwrap();
        assert!(wait_until(Duration::from_secs(2), || got.lock().unwrap().len() == 1));
        assert_eq!(got.lock().unwrap()[0], f);
        global().deregister(tok);
    }

    #[test]
    fn deregister_mid_frame_evicts_partial_bytes() {
        let listener = crate::sfm::tcp::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = std::net::TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        let got = Arc::new(Mutex::new(Vec::new()));
        let closed = Arc::new(AtomicBool::new(false));
        let tok = global().register(
            Registration::Tcp {
                stream,
                verify_crc: true,
            },
            Box::new(CollectSink {
                got: got.clone(),
                closed: closed.clone(),
            }),
        );
        // half a frame: length prefix + a fraction of the body
        let bytes = frame(0, vec![3u8; 4096]).encode();
        let mut wire = (bytes.len() as u32).to_le_bytes().to_vec();
        wire.extend_from_slice(&bytes);
        let partial = wire.len() / 2;
        client.write_all(&wire[..partial]).unwrap();
        client.flush().unwrap();
        // wait until the reactor has buffered the partial bytes
        std::thread::sleep(Duration::from_millis(50));
        let before = mem::evicted_bytes();
        global().deregister(tok);
        // the counter is process-global and cumulative: assert the delta
        // covers at least our partial buffer
        assert!(
            wait_until(Duration::from_secs(2), || {
                mem::evicted_bytes() - before >= partial as u64
            }),
            "partial frame not evicted: delta={}",
            mem::evicted_bytes() - before
        );
        assert!(got.lock().unwrap().is_empty());
    }

    #[test]
    fn interval_tasks_tick_and_cancel() {
        let count = Arc::new(Mutex::new(0u32));
        let c = count.clone();
        global().add_interval(
            Duration::from_millis(10),
            Box::new(move || {
                let mut n = c.lock().unwrap();
                *n += 1;
                *n < 3 // self-cancel after 3 ticks
            }),
        );
        assert!(wait_until(Duration::from_secs(2), || *count.lock().unwrap() == 3));
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(*count.lock().unwrap(), 3, "interval kept firing after cancel");

        let c2 = Arc::new(Mutex::new(0u32));
        let c2c = c2.clone();
        let id = global().add_interval(
            Duration::from_millis(5),
            Box::new(move || {
                *c2c.lock().unwrap() += 1;
                true
            }),
        );
        assert!(wait_until(Duration::from_secs(2), || *c2.lock().unwrap() >= 2));
        global().cancel_interval(id);
        let frozen = *c2.lock().unwrap();
        std::thread::sleep(Duration::from_millis(50));
        assert!(*c2.lock().unwrap() <= frozen + 1, "cancel_interval ignored");
    }

    #[test]
    fn listener_accepts_without_blocking() {
        let listener = crate::sfm::tcp::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let accepted = Arc::new(Mutex::new(Vec::new()));
        let acc = accepted.clone();
        let tok = global()
            .register_listener(
                listener,
                Box::new(move |_stream, peer| {
                    acc.lock().unwrap().push(peer);
                }),
            )
            .unwrap();
        let clients: Vec<_> = (0..5)
            .map(|_| std::net::TcpStream::connect(addr).unwrap())
            .collect();
        assert!(
            wait_until(Duration::from_secs(2), || accepted.lock().unwrap().len() == 5),
            "accepted {} of 5",
            accepted.lock().unwrap().len()
        );
        global().deregister(tok);
        drop(clients);
    }
}
