//! The event-driven fleet core: **one** reactor thread owns every
//! registered connection, so resident thread count is O(cores + active
//! jobs) instead of O(clients).
//!
//! Before this module, each fleet connection cost a dedicated receive
//! pump thread (blocking `Driver::recv`) plus a heartbeat thread — 512
//! simulated clients passed, 10 000 could not even be spawned. The
//! reactor inverts that model:
//!
//! ```text
//!                         ┌──────────────────────────────┐
//!   TcpStream (nonblock) ─┤                              │
//!   TcpStream (nonblock) ─┤        sfm-reactor           │──▶ MuxSink
//!   inproc rx + ReadyHook─┤  poll / readiness / decode   │──▶ MuxSink
//!   inproc rx + ReadyHook─┤  + one timer wheel           │──▶ ...
//!                         │  (heartbeats, throttle       │
//!                         │   resumes, fleet sweeps)     │
//!                         └──────────────────────────────┘
//! ```
//!
//! * **TCP** connections are switched to non-blocking mode and polled;
//!   incoming bytes accumulate in a per-connection partial buffer and
//!   complete `u32 len | frame` records are decoded incrementally. A
//!   connection deregistered mid-frame drops its partial bytes into
//!   [`mem::track_evicted`] — never leaked, never delivered torn.
//! * **In-process** connections ride the same loop through a
//!   [`ReadyHook`]: the sending side pokes the reactor after each
//!   channel push, so inproc delivery stays event-driven (no polling
//!   tax), with a slow probe sweep catching peer-drop disconnects.
//! * **Timers** (heartbeat sends, throttle resume deadlines, the fleet
//!   suspect/gone sweep) share one wheel, so "periodic work" no longer
//!   implies "a parked thread".
//!
//! Frames are handed to a [`FrameSink`] (the mux's routing/priority
//! logic). The sink always takes ownership of the frame — when receive
//! throttling has no budget the sink *parks* data frames internally and
//! answers with [`SinkStatus::Resume`], so the reactor thread never
//! blocks in a token bucket. Control frames (heartbeats, FIN, job 0)
//! bypass parking entirely — the priority lane that keeps a heartbeat
//! from queueing behind a multi-megabyte tensor transfer.
//!
//! This is the only module under `rust/src/sfm/` and `rust/src/fleet/`
//! allowed to spawn threads (CI enforces it; see
//! `scripts/check_no_thread_spawn.sh`): the reactor thread itself, plus
//! [`spawn_blocking_pump`] — the legacy escape hatch for driver stacks
//! that cannot express readiness.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet};
use std::io::Read;
use std::net::TcpStream;
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use super::{Driver, Frame, SfmError};
use crate::util::mem;

/// Identifies one registered connection.
pub type Token = u64;
/// Identifies one interval task on the timer wheel.
pub type TimerId = u64;
/// An interval task: runs every period on the reactor thread; return
/// `false` to cancel.
pub type IntervalFn = Box<dyn FnMut() -> bool + Send>;

/// Poll cadence for non-blocking TCP sockets (no epoll in the offline
/// crate set, so readiness is sampled; each sample drains everything
/// available, bounding per-connection throughput at MB/ms scale).
const TCP_POLL: Duration = Duration::from_millis(1);
/// Probe cadence for in-process queues: normally event-driven via
/// [`ReadyHook`], this sweep only exists to notice peers that dropped
/// their sender without a final frame.
const QUEUE_PROBE: Duration = Duration::from_millis(250);
/// Per-connection read budget per service round, so one firehose
/// connection cannot starve the rest of the loop.
const MAX_READ_PER_ROUND: usize = 1 << 20;

/// How a receive endpoint plugs into the reactor (see
/// [`Driver::registration`]).
pub enum Registration {
    /// A TCP socket, switched to non-blocking and polled. NOTE: the
    /// socket's send half (a `try_clone` sharing the same file
    /// description) becomes non-blocking too — [`super::tcp::TcpDriver`]'s
    /// send path retries `WouldBlock` to preserve blocking semantics for
    /// its callers.
    Tcp { stream: TcpStream, verify_crc: bool },
    /// An in-process frame queue plus the hook its sender pokes.
    Queue {
        rx: Arc<Mutex<Receiver<Frame>>>,
        hook: ReadyHook,
    },
}

/// Shared between an in-process sender and the reactor: once the peer's
/// receive half is registered, every send pokes the reactor awake.
#[derive(Clone, Default)]
pub struct ReadyHook {
    token: Arc<Mutex<Option<Token>>>,
}

impl ReadyHook {
    /// Called by the sending side after pushing a frame.
    pub fn notify(&self) {
        let tok = *self.token.lock().unwrap();
        if let Some(tok) = tok {
            global().mark_ready(tok);
        }
    }

    fn bind(&self, tok: Token) {
        *self.token.lock().unwrap() = Some(tok);
    }
}

/// Verdict a [`FrameSink`] returns to the reactor.
pub enum SinkStatus {
    /// Keep feeding frames as they arrive.
    Ready,
    /// The sink parked work it could not admit yet (throttle budget):
    /// call [`FrameSink::on_resume`] at `at`. If `pause_reads` the sink's
    /// parking buffer is full — stop reading the transport until then
    /// (kernel/window backpressure takes over).
    Resume { at: Instant, pause_reads: bool },
    /// Deregister the connection.
    Closed,
}

/// Where decoded frames go. Implemented by the mux's routing logic; the
/// sink always takes ownership of the frame (parking it internally if
/// throttled), so the reactor never has to un-read anything.
pub trait FrameSink: Send {
    /// A complete frame arrived.
    fn on_frame(&mut self, frame: Frame) -> SinkStatus;
    /// A previously returned `Resume` deadline elapsed.
    fn on_resume(&mut self) -> SinkStatus;
    /// The transport died; the reactor deregisters after this call.
    fn on_closed(&mut self, err: SfmError);
}

enum Source {
    Tcp(TcpSource),
    Queue { rx: Arc<Mutex<Receiver<Frame>>> },
}

struct TcpSource {
    stream: TcpStream,
    verify_crc: bool,
    /// Partial-frame accumulation buffer.
    buf: Vec<u8>,
}

impl Drop for TcpSource {
    fn drop(&mut self) {
        // Killed / closed mid-frame: the half-decoded bytes are evicted,
        // not leaked and never delivered torn.
        if !self.buf.is_empty() {
            mem::track_evicted(self.buf.len());
        }
    }
}

struct Conn {
    source: Source,
    sink: Box<dyn FrameSink>,
    reads_paused: bool,
    /// A Resume timer is already queued for this connection.
    resume_pending: bool,
    closed: bool,
}

struct ConnSlot {
    conn: Arc<Mutex<Conn>>,
    is_tcp: bool,
}

enum TimerKind {
    Resume(Token),
    Interval(TimerId),
}

struct TimerEntry {
    at: Instant,
    seq: u64,
    kind: TimerKind,
}

impl PartialEq for TimerEntry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for TimerEntry {}
impl PartialOrd for TimerEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for TimerEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

struct IntervalTask {
    period: Duration,
    /// Taken out while running (outside the reactor lock).
    f: Option<IntervalFn>,
}

#[derive(Default)]
struct Inner {
    conns: HashMap<Token, ConnSlot>,
    ready: HashSet<Token>,
    timers: BinaryHeap<Reverse<TimerEntry>>,
    intervals: HashMap<TimerId, IntervalTask>,
    next_token: u64,
    next_id: u64,
    tcp_conns: usize,
}

impl Inner {
    fn push_timer(&mut self, at: Instant, kind: TimerKind) {
        let seq = self.next_id;
        self.next_id += 1;
        self.timers.push(Reverse(TimerEntry { at, seq, kind }));
    }
}

/// The process-wide reactor (one thread, started on first use).
pub struct Reactor {
    inner: Mutex<Inner>,
    cv: Condvar,
}

/// The process-wide reactor instance.
pub fn global() -> &'static Reactor {
    static GLOBAL: OnceLock<&'static Reactor> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let r: &'static Reactor = Box::leak(Box::new(Reactor {
            inner: Mutex::new(Inner::default()),
            cv: Condvar::new(),
        }));
        std::thread::Builder::new()
            .name("sfm-reactor".into())
            .stack_size(512 << 10)
            .spawn(move || r.run_loop())
            .expect("spawn sfm-reactor");
        r
    })
}

impl Reactor {
    /// Register a connection; frames flow into `sink` from now on.
    pub fn register(&self, reg: Registration, sink: Box<dyn FrameSink>) -> Token {
        let (token, hook) = {
            let mut inner = self.inner.lock().unwrap();
            let token = inner.next_token;
            inner.next_token += 1;
            let (source, hook, is_tcp) = match reg {
                Registration::Tcp { stream, verify_crc } => {
                    let _ = stream.set_nonblocking(true);
                    inner.tcp_conns += 1;
                    (
                        Source::Tcp(TcpSource {
                            stream,
                            verify_crc,
                            buf: Vec::new(),
                        }),
                        None,
                        true,
                    )
                }
                Registration::Queue { rx, hook } => {
                    (Source::Queue { rx }, Some(hook), false)
                }
            };
            inner.conns.insert(
                token,
                ConnSlot {
                    conn: Arc::new(Mutex::new(Conn {
                        source,
                        sink,
                        reads_paused: false,
                        resume_pending: false,
                        closed: false,
                    })),
                    is_tcp,
                },
            );
            (token, hook)
        };
        // Bind outside the reactor lock (hook lock then reactor lock is
        // the sender's order; never nest the other way).
        if let Some(hook) = hook {
            hook.bind(token);
        }
        // Frames may predate registration (or the bind above): service once.
        self.mark_ready(token);
        token
    }

    /// Remove a connection. The sink is dropped without `on_closed`; a
    /// TCP partial-frame buffer is accounted as evicted.
    pub fn deregister(&self, token: Token) {
        let slot = {
            let mut inner = self.inner.lock().unwrap();
            let slot = inner.conns.remove(&token);
            inner.ready.remove(&token);
            if slot.as_ref().is_some_and(|s| s.is_tcp) {
                inner.tcp_conns -= 1;
            }
            slot
        };
        // Drop outside the lock: TcpSource::drop tracks torn-frame bytes
        // and the sink's drop may run arbitrary (mux) code.
        drop(slot);
    }

    /// Wake the reactor: `token` has frames queued.
    pub fn mark_ready(&self, token: Token) {
        let mut inner = self.inner.lock().unwrap();
        if inner.conns.contains_key(&token) {
            inner.ready.insert(token);
            self.cv.notify_all();
        }
    }

    /// Run `f` every `period` on the reactor thread until it returns
    /// `false` (or [`Reactor::cancel_interval`]). First run after one
    /// period.
    pub fn add_interval(&self, period: Duration, f: IntervalFn) -> TimerId {
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_id;
        inner.next_id += 1;
        inner.intervals.insert(id, IntervalTask { period, f: Some(f) });
        inner.push_timer(Instant::now() + period, TimerKind::Interval(id));
        self.cv.notify_all();
        id
    }

    /// Cancel an interval task (no-op if already finished).
    pub fn cancel_interval(&self, id: TimerId) {
        self.inner.lock().unwrap().intervals.remove(&id);
    }

    // ------------------------------------------------------------ loop

    fn run_loop(&self) {
        let mut last_probe = Instant::now();
        loop {
            let mut resumes: Vec<(Token, Arc<Mutex<Conn>>)> = Vec::new();
            let mut intervals: Vec<(TimerId, IntervalFn, Duration)> = Vec::new();
            let mut service: Vec<(Token, Arc<Mutex<Conn>>)> = Vec::new();
            {
                let mut inner = self.inner.lock().unwrap();
                let now = Instant::now();
                while let Some(Reverse(top)) = inner.timers.peek() {
                    if top.at > now {
                        break;
                    }
                    let Reverse(entry) = inner.timers.pop().unwrap();
                    match entry.kind {
                        TimerKind::Resume(tok) => {
                            if let Some(slot) = inner.conns.get(&tok) {
                                resumes.push((tok, slot.conn.clone()));
                            }
                        }
                        TimerKind::Interval(id) => {
                            if let Some(task) = inner.intervals.get_mut(&id) {
                                if let Some(f) = task.f.take() {
                                    intervals.push((id, f, task.period));
                                }
                            }
                        }
                    }
                }
                let probe = now.duration_since(last_probe) >= QUEUE_PROBE;
                if probe {
                    last_probe = now;
                }
                let ready: HashSet<Token> = inner.ready.drain().collect();
                for tok in &ready {
                    if let Some(slot) = inner.conns.get(tok) {
                        service.push((*tok, slot.conn.clone()));
                    }
                }
                if inner.tcp_conns > 0 || probe {
                    for (tok, slot) in inner.conns.iter() {
                        if (slot.is_tcp || probe) && !ready.contains(tok) {
                            service.push((*tok, slot.conn.clone()));
                        }
                    }
                }
            }

            for (tok, conn) in &resumes {
                self.service(*tok, conn, true);
            }
            for (tok, conn) in &service {
                self.service(*tok, conn, false);
            }
            for (id, mut f, period) in intervals {
                let keep = f();
                let mut inner = self.inner.lock().unwrap();
                if !keep {
                    inner.intervals.remove(&id);
                    continue;
                }
                // put the closure back unless it was cancelled mid-run
                if let Some(task) = inner.intervals.get_mut(&id) {
                    task.f = Some(f);
                    inner.push_timer(Instant::now() + period, TimerKind::Interval(id));
                }
            }

            let inner = self.inner.lock().unwrap();
            if !inner.ready.is_empty() {
                continue;
            }
            let now = Instant::now();
            let mut wait = if inner.tcp_conns > 0 { TCP_POLL } else { QUEUE_PROBE };
            if let Some(Reverse(top)) = inner.timers.peek() {
                wait = wait.min(top.at.saturating_duration_since(now));
            }
            if wait.is_zero() {
                continue;
            }
            let _ = self.cv.wait_timeout(inner, wait);
        }
    }

    /// Drain one connection's source into its sink.
    fn service(&self, token: Token, conn: &Mutex<Conn>, resume: bool) {
        let mut c = conn.lock().unwrap();
        if c.closed {
            return;
        }
        if resume {
            c.resume_pending = false;
            c.reads_paused = false;
            let status = c.sink.on_resume();
            if !self.apply(&mut c, token, status) && (c.closed || c.reads_paused) {
                return;
            }
        }
        if c.reads_paused {
            return;
        }
        let rx = match &c.source {
            Source::Queue { rx } => Some(rx.clone()),
            Source::Tcp(_) => None,
        };
        match rx {
            Some(rx) => loop {
                if c.closed || c.reads_paused {
                    return;
                }
                let polled = rx.lock().unwrap().try_recv();
                match polled {
                    Ok(frame) => {
                        let status = c.sink.on_frame(frame);
                        self.apply(&mut c, token, status);
                    }
                    Err(TryRecvError::Empty) => return,
                    Err(TryRecvError::Disconnected) => {
                        self.close_conn(&mut c, token, SfmError::Closed);
                        return;
                    }
                }
            },
            None => self.service_tcp(&mut c, token),
        }
    }

    fn service_tcp(&self, c: &mut Conn, token: Token) {
        loop {
            if c.closed || c.reads_paused {
                return;
            }
            // 1) pull bytes + slice complete frames, borrowing the source
            let (frames, read_n, fail) = {
                let Source::Tcp(src) = &mut c.source else {
                    return;
                };
                read_and_decode(src)
            };
            // 2) feed decoded frames (the sink owns them even if it
            //    answers with backpressure mid-batch)
            for frame in frames {
                let status = c.sink.on_frame(frame);
                self.apply(c, token, status);
                if c.closed {
                    return;
                }
            }
            if let Some(err) = fail {
                self.close_conn(c, token, err);
                return;
            }
            if read_n < MAX_READ_PER_ROUND {
                return; // drained (WouldBlock); next poll round continues
            }
        }
    }

    /// Apply a sink verdict; `true` = keep feeding.
    fn apply(&self, c: &mut Conn, token: Token, status: SinkStatus) -> bool {
        match status {
            SinkStatus::Ready => true,
            SinkStatus::Resume { at, pause_reads } => {
                if pause_reads {
                    c.reads_paused = true;
                }
                if !c.resume_pending {
                    c.resume_pending = true;
                    let mut inner = self.inner.lock().unwrap();
                    inner.push_timer(at, TimerKind::Resume(token));
                }
                !pause_reads
            }
            SinkStatus::Closed => {
                c.closed = true;
                self.deregister(token);
                false
            }
        }
    }

    fn close_conn(&self, c: &mut Conn, token: Token, err: SfmError) {
        c.closed = true;
        c.sink.on_closed(err);
        self.deregister(token);
    }
}

/// Read available bytes (non-blocking) and slice out complete frames.
/// Returns `(frames, bytes_read, fatal_error)`.
fn read_and_decode(src: &mut TcpSource) -> (Vec<Frame>, usize, Option<SfmError>) {
    use std::io::ErrorKind;
    let mut tmp = [0u8; 16 << 10];
    let mut read_n = 0;
    let mut fail = None;
    loop {
        match src.stream.read(&mut tmp) {
            Ok(0) => {
                fail = Some(SfmError::Closed);
                break;
            }
            Ok(n) => {
                src.buf.extend_from_slice(&tmp[..n]);
                read_n += n;
                if read_n >= MAX_READ_PER_ROUND {
                    break;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::ConnectionReset
                        | ErrorKind::ConnectionAborted
                        | ErrorKind::UnexpectedEof
                ) =>
            {
                fail = Some(SfmError::Closed);
                break;
            }
            Err(e) => {
                fail = Some(SfmError::Io(e));
                break;
            }
        }
    }
    let mut frames = Vec::new();
    let mut off = 0;
    while src.buf.len().saturating_sub(off) >= 4 {
        let len =
            u32::from_le_bytes([src.buf[off], src.buf[off + 1], src.buf[off + 2], src.buf[off + 3]])
                as usize;
        if len > (1 << 30) {
            fail = Some(SfmError::Decode(format!("implausible frame length {len}")));
            break;
        }
        if src.buf.len() - off - 4 < len {
            break; // partial frame: wait for more bytes
        }
        match Frame::decode(&src.buf[off + 4..off + 4 + len], src.verify_crc) {
            Ok(f) => frames.push(f),
            Err(e) => {
                // a poisoned stream cannot be resynchronized: sever
                fail = Some(e);
                break;
            }
        }
        off += 4 + len;
    }
    src.buf.drain(..off);
    (frames, read_n, fail)
}

/// Legacy fallback for driver stacks without a [`Driver::registration`]:
/// one dedicated pump thread with the pre-reactor blocking semantics.
/// Kept so arbitrary decorator combinations still work; nothing in the
/// repo's standard paths uses it.
pub fn spawn_blocking_pump(mut driver: Box<dyn Driver>, mut sink: Box<dyn FrameSink>) {
    let name = format!("mux-pump({})", driver.name());
    std::thread::Builder::new()
        .name(name)
        .stack_size(256 << 10)
        .spawn(move || loop {
            match driver.recv() {
                Ok(frame) => {
                    let mut status = sink.on_frame(frame);
                    loop {
                        match status {
                            SinkStatus::Ready => break,
                            SinkStatus::Closed => return,
                            SinkStatus::Resume { at, .. } => {
                                let now = Instant::now();
                                if at > now {
                                    std::thread::sleep(at - now);
                                }
                                status = sink.on_resume();
                            }
                        }
                    }
                }
                Err(err) => {
                    sink.on_closed(err);
                    return;
                }
            }
        })
        .expect("spawn mux pump");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sfm::{inproc, FLAG_FIRST, FLAG_LAST};
    use std::io::Write;
    use std::sync::atomic::{AtomicBool, Ordering};

    struct CollectSink {
        got: Arc<Mutex<Vec<Frame>>>,
        closed: Arc<AtomicBool>,
    }

    impl FrameSink for CollectSink {
        fn on_frame(&mut self, frame: Frame) -> SinkStatus {
            self.got.lock().unwrap().push(frame);
            SinkStatus::Ready
        }
        fn on_resume(&mut self) -> SinkStatus {
            SinkStatus::Ready
        }
        fn on_closed(&mut self, _err: SfmError) {
            self.closed.store(true, Ordering::SeqCst);
        }
    }

    fn frame(seq: u32, payload: Vec<u8>) -> Frame {
        Frame {
            flags: FLAG_FIRST | FLAG_LAST,
            kind: 7,
            job: 0,
            stream: 1,
            seq,
            total: 1,
            payload,
        }
    }

    fn wait_until(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
        let t0 = Instant::now();
        while t0.elapsed() < timeout {
            if cond() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        cond()
    }

    #[test]
    fn inproc_queue_rides_the_reactor() {
        let (mut a, b) = inproc::pair(16, "reactor-q");
        let got = Arc::new(Mutex::new(Vec::new()));
        let closed = Arc::new(AtomicBool::new(false));
        let mut recv = b.recv_half();
        let reg = recv.registration().expect("inproc recv half registers");
        let tok = global().register(
            reg,
            Box::new(CollectSink {
                got: got.clone(),
                closed: closed.clone(),
            }),
        );
        for i in 0..5 {
            a.send(frame(i, vec![i as u8; 64])).unwrap();
        }
        assert!(
            wait_until(Duration::from_secs(2), || got.lock().unwrap().len() == 5),
            "frames not delivered: {}",
            got.lock().unwrap().len()
        );
        // peer drop is noticed by the probe sweep
        drop(a);
        drop(b);
        assert!(wait_until(Duration::from_secs(2), || closed
            .load(Ordering::SeqCst)));
        global().deregister(tok); // idempotent after close
    }

    #[test]
    fn tcp_conn_decodes_incrementally() {
        let listener = crate::sfm::tcp::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = std::net::TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        let got = Arc::new(Mutex::new(Vec::new()));
        let closed = Arc::new(AtomicBool::new(false));
        let tok = global().register(
            Registration::Tcp {
                stream,
                verify_crc: true,
            },
            Box::new(CollectSink {
                got: got.clone(),
                closed: closed.clone(),
            }),
        );
        // send one frame in two halves with a pause in between
        let f = frame(0, vec![9u8; 300]);
        let bytes = f.encode();
        let mut wire = (bytes.len() as u32).to_le_bytes().to_vec();
        wire.extend_from_slice(&bytes);
        let mid = wire.len() / 2;
        client.write_all(&wire[..mid]).unwrap();
        client.flush().unwrap();
        std::thread::sleep(Duration::from_millis(30));
        assert!(got.lock().unwrap().is_empty(), "torn frame delivered");
        client.write_all(&wire[mid..]).unwrap();
        assert!(wait_until(Duration::from_secs(2), || got.lock().unwrap().len() == 1));
        assert_eq!(got.lock().unwrap()[0], f);
        global().deregister(tok);
    }

    #[test]
    fn deregister_mid_frame_evicts_partial_bytes() {
        let listener = crate::sfm::tcp::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = std::net::TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        let got = Arc::new(Mutex::new(Vec::new()));
        let closed = Arc::new(AtomicBool::new(false));
        let tok = global().register(
            Registration::Tcp {
                stream,
                verify_crc: true,
            },
            Box::new(CollectSink {
                got: got.clone(),
                closed: closed.clone(),
            }),
        );
        // half a frame: length prefix + a fraction of the body
        let bytes = frame(0, vec![3u8; 4096]).encode();
        let mut wire = (bytes.len() as u32).to_le_bytes().to_vec();
        wire.extend_from_slice(&bytes);
        let partial = wire.len() / 2;
        client.write_all(&wire[..partial]).unwrap();
        client.flush().unwrap();
        // wait until the reactor has buffered the partial bytes
        std::thread::sleep(Duration::from_millis(50));
        let before = mem::evicted_bytes();
        global().deregister(tok);
        // the counter is process-global and cumulative: assert the delta
        // covers at least our partial buffer
        assert!(
            wait_until(Duration::from_secs(2), || {
                mem::evicted_bytes() - before >= partial as u64
            }),
            "partial frame not evicted: delta={}",
            mem::evicted_bytes() - before
        );
        assert!(got.lock().unwrap().is_empty());
    }

    #[test]
    fn interval_tasks_tick_and_cancel() {
        let count = Arc::new(Mutex::new(0u32));
        let c = count.clone();
        global().add_interval(
            Duration::from_millis(10),
            Box::new(move || {
                let mut n = c.lock().unwrap();
                *n += 1;
                *n < 3 // self-cancel after 3 ticks
            }),
        );
        assert!(wait_until(Duration::from_secs(2), || *count.lock().unwrap() == 3));
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(*count.lock().unwrap(), 3, "interval kept firing after cancel");

        let c2 = Arc::new(Mutex::new(0u32));
        let c2c = c2.clone();
        let id = global().add_interval(
            Duration::from_millis(5),
            Box::new(move || {
                *c2c.lock().unwrap() += 1;
                true
            }),
        );
        assert!(wait_until(Duration::from_secs(2), || *c2.lock().unwrap() >= 2));
        global().cancel_interval(id);
        let frozen = *c2.lock().unwrap();
        std::thread::sleep(Duration::from_millis(50));
        assert!(*c2.lock().unwrap() <= frozen + 1, "cancel_interval ignored");
    }
}
