//! TCP SFM driver: frames over a `TcpStream`, blocking I/O.
//!
//! Wire format: each frame is sent as `u32 len | frame bytes`
//! ([`Frame::encode`]). The kernel socket buffer plus blocking writes
//! provide backpressure; CRC verification on receive is controlled by the
//! job's [`crate::config::StreamConfig`].
//!
//! (The paper's SFM runs over gRPC/HTTP/TCP drivers; with the offline
//! crate set, TCP is the real-network driver and the in-process channel
//! driver stands in for the rest — the point being that the upper layers
//! cannot tell the difference.)

use std::io::{IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::{Driver, Frame, SfmError, FRAME_HEADER_MAX};
use crate::util::mem;

/// Blocking TCP driver (one per connection endpoint).
///
/// When the receive half of a connection is handed to the
/// [`crate::sfm::reactor`] (see [`Driver::registration`]), the reactor
/// switches the socket to non-blocking mode — and because `try_clone`
/// handles share one file description, the *send* half becomes
/// non-blocking too. The send path therefore retries `WouldBlock`
/// internally, preserving blocking semantics for callers either way.
pub struct TcpDriver {
    stream: TcpStream,
    verify_crc: bool,
    label: String,
    /// Set by [`TcpDriver::set_read_timeout`]: when a deadline is
    /// configured, `WouldBlock` on the read path means "timed out" and is
    /// surfaced instead of retried.
    read_timeout: Option<Duration>,
}

impl TcpDriver {
    /// Connect to a server endpoint.
    pub fn connect(addr: impl ToSocketAddrs, verify_crc: bool) -> Result<TcpDriver, SfmError> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let label = format!("tcp:{}", stream.peer_addr()?);
        Ok(TcpDriver {
            stream,
            verify_crc,
            label,
            read_timeout: None,
        })
    }

    /// Wrap an accepted connection.
    pub fn from_stream(stream: TcpStream, verify_crc: bool) -> Result<TcpDriver, SfmError> {
        stream.set_nodelay(true)?;
        let label = format!("tcp:{}", stream.peer_addr()?);
        Ok(TcpDriver {
            stream,
            verify_crc,
            label,
            read_timeout: None,
        })
    }

    /// Set a read timeout (None = block forever).
    pub fn set_read_timeout(&mut self, d: Option<Duration>) -> Result<(), SfmError> {
        self.stream.set_read_timeout(d)?;
        self.read_timeout = d;
        Ok(())
    }

    /// Clone the socket into an independent handle, so one thread can
    /// send while another receives (the mux split). `shutdown` on either
    /// handle closes both.
    pub fn try_clone(&self) -> Result<TcpDriver, SfmError> {
        Ok(TcpDriver {
            stream: self.stream.try_clone()?,
            verify_crc: self.verify_crc,
            label: self.label.clone(),
            read_timeout: self.read_timeout,
        })
    }

    pub fn peer(&self) -> String {
        self.label.clone()
    }
}

/// Largest stack wire header: `u32 len` prefix + frame header.
const WIRE_HEADER_MAX: usize = 4 + FRAME_HEADER_MAX;

/// Build a frame's `u32 len | frame header` wire prefix on the stack;
/// returns the buffer and its encoded length. The payload is vector-
/// written next to it, so nothing is concatenated on the heap — write
/// atomicity over a shared socket clone comes from the mux's send lock.
fn wire_header(frame: &Frame) -> ([u8; WIRE_HEADER_MAX], usize) {
    let mut hdr = [0u8; FRAME_HEADER_MAX];
    let n = frame.encode_header_into(&mut hdr);
    let mut out = [0u8; WIRE_HEADER_MAX];
    out[..4].copy_from_slice(&((n + frame.payload.len()) as u32).to_le_bytes());
    out[4..4 + n].copy_from_slice(&hdr[..n]);
    (out, 4 + n)
}

impl Driver for TcpDriver {
    fn send(&mut self, frame: Frame) -> Result<(), SfmError> {
        let (hdr, hn) = wire_header(&frame);
        write_vectored_from(&mut self.stream, &[&hdr[..hn], &frame.payload], 0, 0)?;
        mem::track_writev(1);
        Ok(())
    }

    fn send_nowait(&mut self, frame: Frame) -> Result<bool, SfmError> {
        let (hdr, hn) = wire_header(&frame);
        let total = hn + frame.payload.len();
        // First attempt: if the socket buffer is completely full the
        // write returns WouldBlock with zero bytes consumed — the frame
        // is safely not-sent and the caller retries next tick. Only a
        // *partial* first write commits us to finishing (abandoning
        // mid-frame would corrupt the stream) — rare, because it needs
        // the buffer to have 1..len-1 free bytes exactly.
        match self
            .stream
            .write_vectored(&[IoSlice::new(&hdr[..hn]), IoSlice::new(&frame.payload)])
        {
            Ok(0) => Err(SfmError::Closed),
            Ok(n) if n == total => {
                mem::track_writev(1);
                Ok(true)
            }
            Ok(n) => {
                let (idx, off) = if n < hn { (0, n) } else { (1, n - hn) };
                write_vectored_from(&mut self.stream, &[&hdr[..hn], &frame.payload], idx, off)?;
                mem::track_writev(1);
                Ok(true)
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(false),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => Ok(false),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::ConnectionReset
                        | std::io::ErrorKind::ConnectionAborted
                        | std::io::ErrorKind::BrokenPipe
                ) =>
            {
                Err(SfmError::Closed)
            }
            Err(e) => Err(SfmError::Io(e)),
        }
    }

    /// Coalesce a batch of ready frames into one writev train: every
    /// frame's wire header goes on the stack and each payload rides as its
    /// own [`IoSlice`] — one syscall per batch at steady state instead of
    /// one per frame.
    fn send_batch(&mut self, frames: Vec<Frame>) -> Result<(), SfmError> {
        if frames.is_empty() {
            return Ok(());
        }
        let mut hdrs = Vec::with_capacity(frames.len());
        for f in &frames {
            hdrs.push(wire_header(f));
        }
        let mut bufs: Vec<&[u8]> = Vec::with_capacity(frames.len() * 2);
        for (f, (hdr, hn)) in frames.iter().zip(&hdrs) {
            bufs.push(&hdr[..*hn]);
            if !f.payload.is_empty() {
                bufs.push(&f.payload);
            }
        }
        write_vectored_from(&mut self.stream, &bufs, 0, 0)?;
        mem::track_writev(frames.len());
        Ok(())
    }

    fn recv(&mut self) -> Result<Frame, SfmError> {
        let mut len_buf = [0u8; 4];
        self.read_exact_or_closed(&mut len_buf)?;
        let len = u32::from_le_bytes(len_buf) as usize;
        // sanity bound: a frame is chunk + ~40B header; 1 GiB guards
        // against a desynchronized stream being misread as a huge length
        if len > (1 << 30) {
            return Err(SfmError::Decode(format!("implausible frame length {len}")));
        }
        let mut buf = vec![0u8; len];
        self.read_exact_or_closed(&mut buf)?;
        Frame::decode(&buf, self.verify_crc)
    }

    fn name(&self) -> String {
        self.label.clone()
    }

    fn shutdown(&mut self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }

    fn registration(&mut self) -> Option<crate::sfm::reactor::Registration> {
        let stream = self.stream.try_clone().ok()?;
        Some(crate::sfm::reactor::Registration::Tcp {
            stream,
            verify_crc: self.verify_crc,
        })
    }
}

impl TcpDriver {
    /// `read_exact` that tracks its own offset, so `WouldBlock` from a
    /// reactor-shared (non-blocking) socket can be retried without losing
    /// bytes. When a read timeout is configured, `WouldBlock`/`TimedOut`
    /// is surfaced as an I/O error instead (timeout semantics).
    fn read_exact_or_closed(&mut self, buf: &mut [u8]) -> Result<(), SfmError> {
        use std::io::ErrorKind;
        let mut off = 0;
        while off < buf.len() {
            match self.stream.read(&mut buf[off..]) {
                Ok(0) => return Err(SfmError::Closed),
                Ok(n) => off += n,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e)
                    if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
                        && self.read_timeout.is_none() =>
                {
                    std::thread::sleep(Duration::from_micros(500));
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        ErrorKind::UnexpectedEof
                            | ErrorKind::ConnectionReset
                            | ErrorKind::ConnectionAborted
                    ) =>
                {
                    return Err(SfmError::Closed);
                }
                Err(e) => return Err(SfmError::Io(e)),
            }
        }
        Ok(())
    }
}

/// Vectored `write_all` starting at (`idx`, `off`) within `bufs`, retrying
/// `WouldBlock` (non-blocking shared socket) with a short sleep —
/// preserving blocking-send semantics across a partial writev.
fn write_vectored_from(
    stream: &mut TcpStream,
    bufs: &[&[u8]],
    mut idx: usize,
    mut off: usize,
) -> Result<(), SfmError> {
    use std::io::ErrorKind;
    let mut win: Vec<IoSlice> = Vec::with_capacity(bufs.len());
    loop {
        // skip consumed (or empty) slices before rebuilding the window
        while idx < bufs.len() && off >= bufs[idx].len() {
            idx += 1;
            off = 0;
        }
        if idx == bufs.len() {
            return Ok(());
        }
        win.clear();
        win.push(IoSlice::new(&bufs[idx][off..]));
        win.extend(bufs[idx + 1..].iter().map(|b| IoSlice::new(b)));
        match stream.write_vectored(&win) {
            Ok(0) => return Err(SfmError::Closed),
            Ok(mut n) => {
                while n > 0 {
                    let rem = bufs[idx].len() - off;
                    if n >= rem {
                        n -= rem;
                        idx += 1;
                        off = 0;
                    } else {
                        off += n;
                        n = 0;
                    }
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_micros(500));
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::ConnectionReset
                        | ErrorKind::ConnectionAborted
                        | ErrorKind::BrokenPipe
                ) =>
            {
                return Err(SfmError::Closed);
            }
            Err(e) => return Err(SfmError::Io(e)),
        }
    }
}

/// Bind a listener (for callers that need the bound port before accepting).
pub fn bind(addr: impl ToSocketAddrs) -> Result<TcpListener, SfmError> {
    Ok(TcpListener::bind(addr)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sfm::{chunk_frames, Reassembler};

    #[test]
    fn tcp_roundtrip_loopback() {
        let listener = bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
        let expected = data.clone();

        let server = std::thread::spawn(move || {
            let (conn, _) = listener.accept().unwrap();
            let mut drv = TcpDriver::from_stream(conn, true).unwrap();
            let mut re = Reassembler::new();
            loop {
                let f = drv.recv().unwrap();
                if let Some((stream, kind, payload)) = re.push(f).unwrap() {
                    crate::util::mem::track_free(payload.len());
                    // echo back a small ack frame
                    drv.send(Frame {
                        flags: crate::sfm::FLAG_FIRST | crate::sfm::FLAG_LAST,
                        kind,
                        job: 0,
                        stream,
                        seq: 0,
                        total: 1,
                        payload: (payload == expected)
                            .then(|| b"ok".to_vec())
                            .unwrap_or_else(|| b"bad".to_vec())
                            .into(),
                    })
                    .unwrap();
                    break;
                }
            }
        });

        let mut client = TcpDriver::connect(addr, true).unwrap();
        for f in chunk_frames(2, 99, &data, 1024) {
            client.send(f).unwrap();
        }
        let ack = client.recv().unwrap();
        assert_eq!(ack.payload, b"ok");
        assert_eq!(ack.stream, 99);
        server.join().unwrap();
    }

    #[test]
    fn closed_connection_detected() {
        let listener = bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (conn, _) = listener.accept().unwrap();
            drop(conn); // immediately close
        });
        let mut client = TcpDriver::connect(addr, true).unwrap();
        server.join().unwrap();
        assert!(matches!(client.recv(), Err(SfmError::Closed)));
    }
}
