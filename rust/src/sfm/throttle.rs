//! Bandwidth-throttling SFM driver decorator (token bucket).
//!
//! Models the paper's Fig-5 setup — Site-1 on a fast link, Site-2 on a
//! slow one — without real cross-region networking: wrap any [`Driver`]
//! and cap its send rate in bytes/second. Because the decorator sits
//! *under* the streaming layer, upper layers experience a slow link
//! exactly as they would in production (send blocks, transfers stretch in
//! time, memory stays resident longer — the effect Fig 5 visualizes).

use std::time::{Duration, Instant};

use super::{Driver, Frame, SfmError};

/// Token-bucket rate limiter.
#[derive(Debug)]
pub struct TokenBucket {
    rate_bps: f64,
    capacity: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    pub fn new(rate_bps: u64, capacity_bytes: u64) -> TokenBucket {
        TokenBucket {
            rate_bps: rate_bps as f64,
            capacity: capacity_bytes as f64,
            tokens: capacity_bytes as f64,
            last: Instant::now(),
        }
    }

    fn refill(&mut self) {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.rate_bps).min(self.capacity);
    }

    /// Block until `n` bytes of budget are available, then consume them.
    pub fn take(&mut self, n: usize) {
        let need = n as f64;
        loop {
            self.refill();
            if self.tokens >= need {
                self.tokens -= need;
                return;
            }
            let deficit = need - self.tokens;
            let wait = (deficit / self.rate_bps).clamp(0.0005, 0.25);
            std::thread::sleep(Duration::from_secs_f64(wait));
        }
    }

    /// Non-blocking variant for tests: consume if available.
    pub fn try_take(&mut self, n: usize) -> bool {
        self.refill();
        if self.tokens >= n as f64 {
            self.tokens -= n as f64;
            true
        } else {
            false
        }
    }

    /// Burst capacity in bytes (a single take larger than this can never
    /// succeed — callers sharing a bucket clamp to it).
    pub fn capacity(&self) -> u64 {
        self.capacity as u64
    }

    /// How long until `n` bytes of budget will have accumulated — the
    /// reactor's throttle-resume deadline, replacing the blocking
    /// [`TokenBucket::take`] sleep loop with a timer. Clamped like the
    /// blocking path so wakeups stay sane.
    pub fn eta(&mut self, n: usize) -> Duration {
        self.refill();
        let deficit = (n as f64).min(self.capacity) - self.tokens;
        if deficit <= 0.0 {
            return Duration::ZERO;
        }
        Duration::from_secs_f64((deficit / self.rate_bps).clamp(0.0005, 0.25))
    }
}

/// Driver decorator applying a send-side bandwidth cap.
pub struct Throttled<D: Driver> {
    inner: D,
    bucket: TokenBucket,
    /// Receive budget still owed from a frame already delivered via the
    /// non-blocking [`Driver::try_recv`] path: the next poll pays it
    /// down before another frame is released, preserving the average
    /// rate without ever blocking a reactor shard.
    recv_debt: usize,
}

impl<D: Driver> Throttled<D> {
    /// Cap `inner`'s send path at `rate_bps` bytes/second. Burst capacity
    /// is one chunk (so pacing is smooth at the chunk granularity the
    /// paper streams at).
    pub fn new(inner: D, rate_bps: u64, burst_bytes: u64) -> Throttled<D> {
        Throttled {
            inner,
            bucket: TokenBucket::new(rate_bps, burst_bytes.max(1)),
            recv_debt: 0,
        }
    }
}

impl<D: Driver> Driver for Throttled<D> {
    fn send(&mut self, frame: Frame) -> Result<(), SfmError> {
        self.bucket.take(frame.payload.len().max(1));
        self.inner.send(frame)
    }

    fn recv(&mut self) -> Result<Frame, SfmError> {
        // Throttle the receive path too: consuming budget per received
        // frame slows our read rate, which (through TCP backpressure /
        // the bounded in-proc window) slows the remote sender — so one
        // endpoint models a slow *link*, both directions, like the
        // paper's Site-2.
        let frame = self.inner.recv()?;
        self.bucket.take(frame.payload.len().max(1));
        Ok(frame)
    }

    fn try_recv(&mut self) -> Result<Option<Frame>, SfmError> {
        // Non-blocking variant for [`super::reactor::spawn_poll_pump`]:
        // settle the previous frame's debt before releasing another, so
        // the average rate matches `recv` without sleeping on a shard.
        if self.recv_debt > 0 {
            if !self.bucket.try_take(self.recv_debt) {
                return Ok(None);
            }
            self.recv_debt = 0;
        }
        match self.inner.try_recv()? {
            Some(frame) => {
                let n = frame.payload.len().max(1);
                if !self.bucket.try_take(n) {
                    self.recv_debt = n;
                }
                Ok(Some(frame))
            }
            None => Ok(None),
        }
    }

    fn name(&self) -> String {
        format!("throttled({})", self.inner.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sfm::inproc;

    #[test]
    fn bucket_enforces_rate() {
        let mut b = TokenBucket::new(10_000, 1_000); // 10 kB/s, 1 kB burst
        assert!(b.try_take(1_000)); // burst drains
        assert!(!b.try_take(1_000)); // empty now
        let t0 = Instant::now();
        b.take(500); // must wait ~50 ms
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(30), "{dt:?}");
    }

    #[test]
    fn bucket_refills_over_time() {
        let mut b = TokenBucket::new(100_000, 10_000);
        assert!(b.try_take(10_000));
        std::thread::sleep(Duration::from_millis(30));
        // ~3000 bytes refilled
        assert!(b.try_take(1_000));
    }

    #[test]
    fn throttled_send_is_slower() {
        let payload = vec![0u8; 2_000];
        let frames = 5;

        let elapsed = |rate: Option<u64>| {
            let (a, mut b) = inproc::pair(64, "thr");
            let mut sender: Box<dyn Driver> = match rate {
                Some(r) => Box::new(Throttled::new(a, r, 2_000)),
                None => Box::new(a),
            };
            let recv = std::thread::spawn(move || {
                for _ in 0..frames {
                    b.recv().unwrap();
                }
            });
            let t0 = Instant::now();
            for i in 0..frames {
                sender
                    .send(Frame {
                        flags: 0,
                        kind: 0,
                        job: 0,
                        stream: 1,
                        seq: i,
                        total: frames,
                        payload: payload.clone().into(),
                    })
                    .unwrap();
            }
            recv.join().unwrap();
            t0.elapsed()
        };

        let fast = elapsed(None);
        // 40 kB/s, 10 kB total => ~200ms (burst covers the first chunk)
        let slow = elapsed(Some(40_000));
        assert!(
            slow > fast + Duration::from_millis(100),
            "fast={fast:?} slow={slow:?}"
        );
    }
}
