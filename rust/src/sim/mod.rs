//! The in-process federation harness: a persistent multiplexed client
//! [`Fleet`] plus the single-job convenience wrapper [`run_job`].
//!
//! Since the session-layer refactor, the fleet — not the job — owns the
//! transports: each client holds **one** connection (in-process channels
//! or real TCP loopback), wrapped in the session mux
//! ([`crate::sfm::mux`]), and every FL job runs over its own multiplexed
//! channel of those shared connections. Per-client bandwidth throttling
//! applies to the connection as a whole (one token bucket per link), so
//! concurrent jobs share a slow site's budget instead of each minting
//! their own. Client processes are modeled by
//! [`MultiJobRuntime`](crate::executor::MultiJobRuntime) cells serviced
//! by **one** fleet-wide control-dispatcher thread: the reactor's
//! delivery callback marks a client dirty when a control frame lands,
//! the dispatcher drains its `job_open`/`job_abort` messages
//! non-blockingly, and only *active* job task loops (one per open job
//! per participating client) own threads — so an idle 10 000-client
//! fleet costs two threads, not 20 000.
//!
//! [`run_job`] is now a thin wrapper: connect a fleet of the job's
//! clients, run the job over it
//! ([`run_one_job`](crate::coordinator::run_one_job)), shut the fleet
//! down. Multi-job serving — `submit`/`status`/`abort`, `max_concurrent`
//! — lives in [`crate::coordinator::JobScheduler`] (see `fedflare serve`).
//!
//! With `job.branching = B > 1` (and more than B clients) a job builds a
//! **2-level aggregator tree**: ⌈N/B⌉ mid-tier nodes each fold a shard of
//! leaves over the shared fleet connections and forward one job-tagged
//! partial per round on a dedicated link — same wire format, same
//! streaming folds.
//!
//! This is the engine behind `fedflare repro *`, the examples, and the
//! integration tests. Multi-process deployment (`fedflare server` /
//! `fedflare client`) shares the same per-job code paths over dedicated
//! (unmuxed) connections.

use std::collections::{BTreeSet, HashMap};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex, RwLock, Weak};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{ClientSpec, FleetConfig, JobConfig, StreamConfig};
use crate::coordinator::OwnedExecutorFactory;
use crate::executor::{JobDirectory, JobStart, MultiJobRuntime};
use crate::fleet::{ClientState, Registry};
use crate::message::FlMessage;
use crate::obs;
use crate::sfm::mux::{JobTagged, MuxConn};
use crate::sfm::{inproc, reactor, tcp, Driver, EvictionPolicy};
use crate::streaming::Messenger;
use crate::tensor::TensorDict;
use crate::util::json::Json;

/// Which transport the simulation uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DriverKind {
    /// Bounded in-process channels.
    InProc,
    /// Real TCP connections over loopback.
    Tcp,
}

/// Build the per-client executor (index, spec) -> Executor.
pub type ExecutorFactory<'a> =
    dyn FnMut(usize, &ClientSpec) -> Result<Box<dyn crate::executor::Executor>> + 'a;

/// What a finished job reports back beyond the controller's own fields.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    /// Peak decoded in-flight gather bytes at the **root** communicator
    /// (per-node counter — mid-tier folds are excluded, unlike the
    /// process-global [`crate::util::mem::gather_peak`]).
    pub root_gather_peak: u64,
}

/// One server-side fleet connection: the shared mux plus the control
/// channel (job 0) the scheduler announces jobs on.
struct FleetConn {
    name: String,
    /// Launch spec, kept so a kill/revive cycle rebuilds the same link
    /// (bandwidth, partition).
    spec: ClientSpec,
    mux: MuxConn,
    control: Mutex<Messenger>,
}

/// One simulated client process: the runtime cell serviced by the
/// fleet's control dispatcher. The runtime is kept whole (instead of
/// being consumed by [`MultiJobRuntime::run`]) so control messages can
/// be fed to it piecewise as the reactor delivers them; `loops` holds
/// the task-loop threads of its currently open jobs — the only
/// per-client threads left, and only while a job is active.
struct ClientCell {
    runtime: MultiJobRuntime,
    control: Messenger,
    /// The client-side mux, kept so churn can sever the client end
    /// deterministically instead of waiting on peer-drop detection.
    mux: MuxConn,
    loops: Vec<(u32, std::thread::JoinHandle<()>)>,
    done: bool,
}

/// The fleet's control dispatcher: a dirty-set + condvar fed by the
/// reactor's per-connection delivery callbacks (`job == 0` ⇒ a control
/// frame landed for that client), drained by one `fleet-dispatch`
/// thread servicing every client cell. Replaces the old
/// one-thread-per-client `fleet-{name}` runtime loops.
struct Dispatch {
    cells: Mutex<HashMap<usize, Arc<Mutex<ClientCell>>>>,
    /// (dirty client indexes, stop flag).
    dirty: Mutex<(BTreeSet<usize>, bool)>,
    /// Deferred membership-kick request (see [`Dispatch::request_kick`]).
    kick: AtomicBool,
    cv: Condvar,
}

impl Dispatch {
    fn new() -> Arc<Dispatch> {
        Arc::new(Dispatch {
            cells: Mutex::new(HashMap::new()),
            dirty: Mutex::new((BTreeSet::new(), false)),
            kick: AtomicBool::new(false),
            cv: Condvar::new(),
        })
    }

    /// Mark one client as having pending control traffic.
    fn mark(&self, idx: usize) {
        self.dirty.lock().unwrap().0.insert(idx);
        self.cv.notify_one();
    }

    /// Mark every cell dirty (the shutdown drain). Never holds the cell
    /// map and dirty locks together — the dispatcher acquires them in
    /// the opposite order.
    fn mark_all(&self) {
        let keys: Vec<usize> = self.cells.lock().unwrap().keys().copied().collect();
        let mut d = self.dirty.lock().unwrap();
        d.0.extend(keys);
        drop(d);
        self.cv.notify_one();
    }

    /// Ask the dispatcher to re-run the fleet's membership callback.
    /// The liveness sweep runs *on the reactor thread* and must never
    /// block on control-plane sends, so it hands the (possibly
    /// blocking) scheduler admission kick over here.
    fn request_kick(&self) {
        self.kick.store(true, Ordering::Relaxed);
        self.cv.notify_one();
    }

    fn stop(&self) {
        self.dirty.lock().unwrap().1 = true;
        self.cv.notify_one();
    }

    fn remove_cell(&self, idx: usize) -> Option<Arc<Mutex<ClientCell>>> {
        self.cells.lock().unwrap().remove(&idx)
    }

    fn all_done(&self) -> bool {
        self.cells
            .lock()
            .unwrap()
            .values()
            .all(|c| c.lock().unwrap().done)
    }
}

/// The `fleet-dispatch` thread body: wait for dirty marks (or a 200 ms
/// sweep tick, which catches any delivery that raced cell
/// installation), service each marked cell, and run deferred
/// membership kicks outside the reactor thread.
fn dispatch_loop(dispatch: Arc<Dispatch>, fleet: Weak<Fleet>) {
    loop {
        let batch: Vec<usize> = {
            let mut d = dispatch.dirty.lock().unwrap();
            loop {
                if d.1 {
                    return;
                }
                if !d.0.is_empty() || dispatch.kick.load(Ordering::Relaxed) {
                    break std::mem::take(&mut d.0).into_iter().collect();
                }
                let (guard, timeout) = dispatch
                    .cv
                    .wait_timeout(d, Duration::from_millis(200))
                    .unwrap();
                d = guard;
                if timeout.timed_out() {
                    drop(d);
                    break dispatch.cells.lock().unwrap().keys().copied().collect();
                }
            }
        };
        for idx in batch {
            let cell = dispatch.cells.lock().unwrap().get(&idx).cloned();
            if let Some(cell) = cell {
                service_cell(&mut cell.lock().unwrap());
            }
        }
        if dispatch.kick.swap(false, Ordering::Relaxed) {
            if let Some(fleet) = fleet.upgrade() {
                fleet.notify_membership();
            }
        }
    }
}

/// Drain one client cell's pending control messages. Nonblocking:
/// returns as soon as the channel is empty or the client is done.
fn service_cell(cell: &mut ClientCell) {
    while !cell.done {
        match cell.control.recv_msg_nonblocking() {
            Ok(Some(msg)) => match cell.runtime.handle_control(msg, &mut cell.loops) {
                Ok(true) => {}
                Ok(false) => finish_cell(cell),
                Err(e) => {
                    obs::log!(warn, "fleet client {}: {e}", cell.runtime.name());
                    finish_cell(cell);
                }
            },
            Ok(None) => return,
            // transport severed (fleet shutdown or a churn kill): unwind
            Err(_) => finish_cell(cell),
        }
    }
}

/// A cell's `bye` path: close and join its job task loops.
fn finish_cell(cell: &mut ClientCell) {
    cell.done = true;
    let loops = std::mem::take(&mut cell.loops);
    cell.runtime.shutdown_jobs(loops);
}

/// Everything the fleet needs to re-deploy a running job onto a client
/// that dropped and rejoined: the job's config plus a shareable executor
/// factory (registered by the scheduler at job start).
pub struct RejoinSpec {
    pub job: JobConfig,
    pub factory: Arc<Mutex<OwnedExecutorFactory>>,
}

/// Per-job control-plane plumbing while a job runs: its rejoin spec,
/// the channel-swap senders of its server-side client handles, and how
/// many client task loops were opened for it (initial + rejoins).
#[derive(Default)]
struct JobPlumbing {
    rejoin: HashMap<u32, RejoinSpec>,
    swaps: HashMap<(u32, String), Sender<Messenger>>,
    opens: HashMap<u32, usize>,
}

/// One unit of rejoin re-deployment work, snapshotted out of the
/// plumbing lock: (job id, job config, executor factory, swap sender).
type RejoinWork = (
    u32,
    JobConfig,
    Arc<Mutex<OwnedExecutorFactory>>,
    Option<Sender<Messenger>>,
);

/// A connected, persistent client fleet (see module docs): the shared
/// transports jobs multiplex over, the in-process [`JobDirectory`], the
/// client-runtime cells standing in for client processes (serviced by
/// one dispatcher thread) — and, since the control-plane refactor,
/// **elastic membership**: clients may be killed, revived, or added
/// while jobs run ([`Fleet::kill_client`] / [`Fleet::revive_client`] /
/// [`Fleet::add_client`] — the churn harness), liveness is observed via
/// heartbeats swept by a reactor timer-wheel task into the shared
/// [`Registry`], and a rejoining client is re-deployed into its running
/// jobs through the registered [`RejoinSpec`]s.
pub struct Fleet {
    conns: RwLock<Vec<Arc<FleetConn>>>,
    kind: DriverKind,
    window: usize,
    verify: bool,
    burst: u64,
    cfg: FleetConfig,
    directory: Arc<JobDirectory>,
    registry: Arc<Registry>,
    /// Client cells + the dirty set their control dispatcher drains.
    dispatch: Arc<Dispatch>,
    dispatcher: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// TCP fleets keep their listener so clients can (re)join later.
    listener: Option<Mutex<std::net::TcpListener>>,
    sweep_stop: Arc<AtomicBool>,
    sweep_timer: Mutex<Option<reactor::TimerId>>,
    plumbing: Mutex<JobPlumbing>,
    /// Serializes kill/revive/add: registry index allocation and the
    /// conns-slot update must agree, and they happen under different
    /// locks — concurrent churn calls would misalign them.
    churn: Mutex<()>,
    /// Invoked (from the sweeper / churn entry points) whenever the
    /// membership epoch changes — the scheduler hooks its admission
    /// re-check here.
    on_membership: Mutex<Option<Box<dyn Fn() + Send>>>,
}

/// Build one muxed inproc connection for `spec`: (server mux, client mux).
fn connect_inproc_pair(spec: &ClientSpec, window: usize, burst: u64) -> (MuxConn, MuxConn) {
    let (s, c) = inproc::pair(window, &spec.name);
    let (sr, cr) = (s.recv_half(), c.recv_half());
    let server_mux = MuxConn::spawn(Box::new(s), Box::new(sr), spec.bandwidth_bps, burst);
    let client_mux = MuxConn::spawn(Box::new(c), Box::new(cr), spec.bandwidth_bps, burst);
    (server_mux, client_mux)
}

/// Build one muxed TCP-loopback connection for `spec` through the
/// fleet's listener: (server mux, client mux).
fn connect_tcp_pair(
    listener: &std::net::TcpListener,
    spec: &ClientSpec,
    verify: bool,
    burst: u64,
) -> Result<(MuxConn, MuxConn)> {
    let addr = listener.local_addr().context("local addr")?;
    let cd = tcp::TcpDriver::connect(addr, verify)?;
    let cdr = cd.try_clone()?;
    let client_mux = MuxConn::spawn(Box::new(cd), Box::new(cdr), spec.bandwidth_bps, burst);
    let (conn, _) = listener.accept().context("accept")?;
    let sd = tcp::TcpDriver::from_stream(conn, verify)?;
    let sdr = sd.try_clone()?;
    let server_mux = MuxConn::spawn(Box::new(sd), Box::new(sdr), spec.bandwidth_bps, burst);
    Ok((server_mux, client_mux))
}

impl Fleet {
    /// Connect one multiplexed connection + client runtime per spec.
    /// `stream` configures the fleet-level links (window, CRC); each job
    /// keeps its own chunking on top. Control-plane knobs take their
    /// defaults (heartbeats on, generous deadlines) — see
    /// [`Fleet::connect_with`].
    pub fn connect(
        specs: &[ClientSpec],
        kind: DriverKind,
        stream: &StreamConfig,
    ) -> Result<Arc<Fleet>> {
        Self::connect_with(specs, kind, stream, FleetConfig::default())
    }

    /// [`Fleet::connect`] with explicit control-plane knobs (heartbeat
    /// cadence, suspect/gone deadlines). A zero heartbeat interval
    /// disables heartbeats and the sweeper: membership is static.
    pub fn connect_with(
        specs: &[ClientSpec],
        kind: DriverKind,
        stream: &StreamConfig,
        cfg: FleetConfig,
    ) -> Result<Arc<Fleet>> {
        let directory = JobDirectory::new();
        let registry = Arc::new(Registry::new());
        let window = stream.window;
        let verify = stream.verify_crc;
        let burst = crate::DEFAULT_CHUNK_BYTES as u64;
        let hb = Duration::from_secs_f64(cfg.heartbeat_interval_s.max(0.0));
        let dispatch = Dispatch::new();
        let mut conns = Vec::with_capacity(specs.len());
        let mut listener = None;
        match kind {
            DriverKind::InProc => {
                for (i, spec) in specs.iter().enumerate() {
                    let idx = registry.join(&spec.name);
                    debug_assert_eq!(idx, i);
                    let (server_mux, client_mux) = connect_inproc_pair(spec, window, burst);
                    deploy_client(&dispatch, spec, i, client_mux, directory.clone(), hb);
                    conns.push(Arc::new(FleetConn::new(spec, server_mux)));
                    registry.connected(i);
                }
            }
            DriverKind::Tcp => {
                let l = tcp::bind("127.0.0.1:0")?;
                for (i, spec) in specs.iter().enumerate() {
                    let idx = registry.join(&spec.name);
                    debug_assert_eq!(idx, i);
                    let (server_mux, client_mux) = connect_tcp_pair(&l, spec, verify, burst)?;
                    deploy_client(&dispatch, spec, i, client_mux, directory.clone(), hb);
                    conns.push(Arc::new(FleetConn::new(spec, server_mux)));
                    registry.connected(i);
                }
                listener = Some(Mutex::new(l));
            }
        }
        let fleet = Arc::new(Fleet {
            conns: RwLock::new(conns),
            kind,
            window,
            verify,
            burst,
            cfg,
            directory,
            registry,
            dispatch,
            dispatcher: Mutex::new(None),
            listener,
            sweep_stop: Arc::new(AtomicBool::new(false)),
            sweep_timer: Mutex::new(None),
            plumbing: Mutex::new(JobPlumbing::default()),
            churn: Mutex::new(()),
            on_membership: Mutex::new(None),
        });
        let d = fleet.dispatch.clone();
        let weak = Arc::downgrade(&fleet);
        let handle = std::thread::Builder::new()
            .name("fleet-dispatch".to_string())
            .spawn(move || dispatch_loop(d, weak))
            .context("spawn fleet dispatcher")?;
        *fleet.dispatcher.lock().unwrap() = Some(handle);
        if hb > Duration::ZERO {
            start_sweep(&fleet);
        }
        Ok(fleet)
    }

    pub fn n_clients(&self) -> usize {
        self.conns.read().unwrap().len()
    }

    pub fn kind(&self) -> DriverKind {
        self.kind
    }

    /// The in-process job registry shared with the client runtimes.
    pub fn directory(&self) -> &Arc<JobDirectory> {
        &self.directory
    }

    /// The fleet's membership/liveness registry (see
    /// [`crate::fleet::Registry`]).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Liveness state of a named client.
    pub fn client_state(&self, name: &str) -> Option<ClientState> {
        self.registry.state_of(name)
    }

    /// Fleet connection index of a client, by name.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.conns.read().unwrap().iter().position(|c| c.name == name)
    }

    /// The connection at `idx` (Arc clone, so callers never hold the
    /// slot lock across blocking sends).
    fn conn(&self, idx: usize) -> Result<Arc<FleetConn>> {
        self.conns
            .read()
            .unwrap()
            .get(idx)
            .cloned()
            .ok_or_else(|| anyhow!("no fleet connection at index {idx}"))
    }

    /// A server-side messenger over client `idx`'s connection, scoped to
    /// `job` (chunking and stale-stream eviction from `stream`).
    pub fn job_messenger(&self, idx: usize, job: u32, stream: &StreamConfig) -> Messenger {
        let conn = self.conn(idx).expect("job_messenger: bad index");
        let mut m = Messenger::new(Box::new(conn.mux.handle(job)), stream.chunk_bytes, 0);
        if let Some(policy) = EvictionPolicy::stale_after_s(stream.stale_stream_age_s) {
            m.set_reassembly_policy(policy);
        }
        m
    }

    /// Announce `job` on client `idx`'s control channel; the client's
    /// runtime claims its start spec from the directory and spawns the
    /// job's task loop. Counted per job so teardown knows how many task
    /// loops (initial + rejoins) will report.
    pub fn open_job(&self, idx: usize, job: u32, name: &str) -> Result<()> {
        let conn = self.conn(idx)?;
        let msg = FlMessage::task("job_open", 0, TensorDict::new())
            .with_meta("job", Json::num(job as f64))
            .with_meta("job_name", Json::str(name));
        conn.control
            .lock()
            .unwrap()
            .send_msg(&msg)
            .map_err(|e| anyhow!("open job {job} on {}: {e}", conn.name))?;
        let mut p = self.plumbing.lock().unwrap();
        *p.opens.entry(job).or_insert(0) += 1;
        Ok(())
    }

    /// Abort `job` fleet-wide: revoke unclaimed deployments, tell every
    /// client to sever the job's channel, and sever the server-side
    /// queues — in-flight streams drain into the eviction counters
    /// ([`crate::util::mem::evicted_bytes`]) instead of stranding buffers.
    pub fn abort_job(&self, job: u32) {
        self.directory.revoke(job);
        let conns: Vec<Arc<FleetConn>> = self.conns.read().unwrap().clone();
        for conn in &conns {
            let msg = FlMessage::task("job_abort", 0, TensorDict::new())
                .with_meta("job", Json::num(job as f64));
            let _ = conn.control.lock().unwrap().send_msg(&msg);
            conn.mux.close_job(job);
        }
    }

    // ------------------------------------------------ control plane

    /// Register a running job's control-plane plumbing. Must run before
    /// the job's first [`Fleet::open_job`]; `rejoin` enables mid-job
    /// re-deployment onto rejoining clients (flat jobs only — tree jobs
    /// keep static membership for now).
    pub fn register_job(&self, job: u32, rejoin: Option<RejoinSpec>) {
        let mut p = self.plumbing.lock().unwrap();
        p.opens.insert(job, 0);
        if let Some(spec) = rejoin {
            p.rejoin.insert(job, spec);
        }
    }

    /// Register the channel-swap sender of `job`'s server-side handle
    /// for `client`: a rejoin delivers the fresh per-job messenger here.
    pub fn register_swap(&self, job: u32, client: &str, swap: Sender<Messenger>) {
        self.plumbing
            .lock()
            .unwrap()
            .swaps
            .insert((job, client.to_string()), swap);
    }

    /// Tear down a job's control-plane plumbing (stops future rejoins
    /// from touching it) and return how many task loops were opened for
    /// it — the number of client reports teardown should wait for.
    pub fn clear_job(&self, job: u32) -> usize {
        let mut p = self.plumbing.lock().unwrap();
        p.rejoin.remove(&job);
        p.swaps.retain(|(j, _), _| *j != job);
        p.opens.remove(&job).unwrap_or(0)
    }

    /// Register the membership-change callback (at most one; the
    /// scheduler's admission kick). Invoked from the dispatcher and
    /// churn entry points — never on the reactor thread, so it may
    /// block (e.g. on control-plane sends).
    pub fn set_membership_listener(&self, cb: Box<dyn Fn() + Send>) {
        *self.on_membership.lock().unwrap() = Some(cb);
    }

    fn notify_membership(&self) {
        if let Some(cb) = self.on_membership.lock().unwrap().as_ref() {
            cb();
        }
    }

    /// Churn harness: abruptly kill a client's connection — transport
    /// severed (no graceful bye), its runtime and task loops unwind and
    /// are reaped, the registry demotes it (`Suspect` now, `Gone` once
    /// the deadline passes). In-flight gathers see the failure through
    /// the existing straggler/quorum path.
    pub fn kill_client(&self, name: &str) -> Result<()> {
        let _churn = self.churn.lock().unwrap();
        let (idx, conn) = {
            let conns = self.conns.read().unwrap();
            let idx = conns
                .iter()
                .position(|c| c.name == name)
                .ok_or_else(|| anyhow!("kill_client: unknown client '{name}'"))?;
            (idx, conns[idx].clone())
        };
        conn.mux.kill();
        self.registry.suspect(idx);
        // tear down the client side: sever its mux too (peer-drop
        // detection would get there, but churn wants determinism) and
        // join its task loops so a later revive starts clean
        if let Some(cell) = self.dispatch.remove_cell(idx) {
            let mut cell = cell.lock().unwrap();
            if !cell.done {
                cell.mux.kill();
                finish_cell(&mut cell);
            }
        }
        self.notify_membership();
        Ok(())
    }

    /// Churn harness: reconnect a previously killed client under its
    /// original spec — fresh transport, fresh runtime, same slot. The
    /// client turns `Joining` → `Live`, the epoch bumps, and every
    /// running job that lists it is re-deployed onto the new connection
    /// (rejoin handshake); it becomes sampleable from the next round.
    pub fn revive_client(&self, name: &str) -> Result<()> {
        let _churn = self.churn.lock().unwrap();
        let spec = {
            let conns = self.conns.read().unwrap();
            let idx = conns
                .iter()
                .position(|c| c.name == name)
                .ok_or_else(|| anyhow!("revive_client: unknown client '{name}'"))?;
            conns[idx].spec.clone()
        };
        let idx = self.registry.join(&spec.name);
        let (server_mux, client_mux) = self.connect_one(&spec)?;
        let hb = Duration::from_secs_f64(self.cfg.heartbeat_interval_s.max(0.0));
        deploy_client(&self.dispatch, &spec, idx, client_mux, self.directory.clone(), hb);
        {
            let mut conns = self.conns.write().unwrap();
            conns[idx] = Arc::new(FleetConn::new(&spec, server_mux));
        }
        self.registry.connected(idx);
        self.handle_rejoin(idx, name);
        self.notify_membership();
        Ok(())
    }

    /// Elastic join: connect a brand-new client while the fleet serves.
    /// It becomes eligible for job admission and for rounds of jobs
    /// submitted after it joined.
    pub fn add_client(&self, spec: &ClientSpec) -> Result<usize> {
        let _churn = self.churn.lock().unwrap();
        if self.index_of(&spec.name).is_some() {
            bail!(
                "add_client: '{}' already in the fleet (revive it instead)",
                spec.name
            );
        }
        let idx = self.registry.join(&spec.name);
        let (server_mux, client_mux) = self.connect_one(spec)?;
        let hb = Duration::from_secs_f64(self.cfg.heartbeat_interval_s.max(0.0));
        deploy_client(&self.dispatch, spec, idx, client_mux, self.directory.clone(), hb);
        {
            let mut conns = self.conns.write().unwrap();
            debug_assert_eq!(conns.len(), idx);
            conns.push(Arc::new(FleetConn::new(spec, server_mux)));
        }
        self.registry.connected(idx);
        self.notify_membership();
        Ok(idx)
    }

    /// Build one fresh connection of the fleet's driver kind.
    fn connect_one(&self, spec: &ClientSpec) -> Result<(MuxConn, MuxConn)> {
        match self.kind {
            DriverKind::InProc => Ok(connect_inproc_pair(spec, self.window, self.burst)),
            DriverKind::Tcp => {
                let listener = self
                    .listener
                    .as_ref()
                    .ok_or_else(|| anyhow!("tcp fleet without a listener"))?;
                let l = listener.lock().unwrap();
                connect_tcp_pair(&l, spec, self.verify, self.burst)
            }
        }
    }

    /// The rejoin handshake: for every running job that lists the
    /// rejoined client, build a fresh executor through the job's
    /// registered factory, offer the deployment, open the job on the new
    /// connection, and hand the job's server-side handle a replacement
    /// channel. Failures are logged, never fatal — the job simply keeps
    /// running without the client.
    fn handle_rejoin(&self, idx: usize, name: &str) {
        let _rejoin_span = obs::span!("rejoin", site: name);
        obs::counter("fleet.rejoins").inc();
        let specs: Vec<RejoinWork> = {
            let p = self.plumbing.lock().unwrap();
            p.rejoin
                .iter()
                .filter(|(_, s)| s.job.clients.iter().any(|c| c.name == name))
                .map(|(id, s)| {
                    (
                        *id,
                        s.job.clone(),
                        s.factory.clone(),
                        p.swaps.get(&(*id, name.to_string())).cloned(),
                    )
                })
                .collect()
        };
        for (job_id, job, factory, swap) in specs {
            // no swap sender yet means the job is still in its deploy/
            // handshake phase (run_flat registers swaps after the
            // initial registrations): re-deploying now would open a
            // task loop no server handle ever reads — a phantom loop
            // that stalls teardown. Skip; the deploy in flight is
            // already targeting the fleet's current connections.
            let Some(swap) = swap else {
                obs::log!(debug, "rejoin {name} into job {job_id}: not yet deployable, skipped");
                continue;
            };
            let i = job
                .clients
                .iter()
                .position(|c| c.name == name)
                .expect("filtered on membership");
            let built = {
                let mut f = factory.lock().unwrap();
                (*f)(i, &job.clients[i])
            };
            let executor = match built {
                Ok(e) => e,
                Err(e) => {
                    obs::log!(warn, "rejoin {name} into job {job_id}: executor build failed: {e}");
                    continue;
                }
            };
            let filters = crate::filters::build_chain(&job.filters, i, job.clients.len());
            self.directory.offer(
                job_id,
                idx,
                JobStart {
                    job_name: job.name.clone(),
                    chunk_bytes: job.stream.chunk_bytes,
                    stale_stream_age_s: job.stream.stale_stream_age_s,
                    executor,
                    filters,
                    enc: job.update_codec,
                    delta: job.delta_updates,
                },
            );
            if let Err(e) = self.open_job(idx, job_id, &job.name) {
                obs::log!(warn, "rejoin {name} into job {job_id}: {e}");
                continue;
            }
            let m = self.job_messenger(idx, job_id, &job.stream);
            if swap.send(m).is_err() {
                obs::log!(debug, "rejoin {name} into job {job_id}: handle already gone");
            }
        }
    }

    /// A dedicated mid-tier link for a hierarchical job: a fresh duplex
    /// pair of the fleet's driver kind, both ends stamping `job` on their
    /// frames. Returns (root side, mid-tier side); the mid-tier side's
    /// stream tag is `tag`.
    pub fn midtier_link(
        &self,
        job: u32,
        stream: &StreamConfig,
        tag: u32,
    ) -> Result<(Messenger, Messenger)> {
        let (down, up): (Box<dyn Driver>, Box<dyn Driver>) = match self.kind {
            DriverKind::InProc => {
                let (a, b) = inproc::pair(self.window, &format!("mid-{job}-{tag}"));
                (Box::new(a), Box::new(b))
            }
            DriverKind::Tcp => {
                let listener = tcp::bind("127.0.0.1:0")?;
                let addr = listener.local_addr().context("midtier addr")?;
                let up = tcp::TcpDriver::connect(addr, self.verify)?;
                let (conn, _) = listener.accept().context("accept midtier")?;
                let down = tcp::TcpDriver::from_stream(conn, self.verify)?;
                (Box::new(down), Box::new(up))
            }
        };
        Ok((
            Messenger::new(
                Box::new(JobTagged::new(down, job)),
                stream.chunk_bytes,
                0,
            ),
            Messenger::new(Box::new(JobTagged::new(up, job)), stream.chunk_bytes, tag),
        ))
    }

    /// End the fleet: cancel the liveness sweep, bye every control
    /// channel, let the dispatcher drain the byes (each cell joins its
    /// job loops), then stop the dispatcher and force-finish anything
    /// left (e.g. clients whose transport already died). Idempotent.
    pub fn shutdown(&self) {
        self.sweep_stop.store(true, Ordering::Relaxed);
        if let Some(id) = self.sweep_timer.lock().unwrap().take() {
            reactor::global().cancel_interval(id);
        }
        let conns: Vec<Arc<FleetConn>> = self.conns.read().unwrap().clone();
        for conn in &conns {
            let _ = conn.control.lock().unwrap().send_msg(&FlMessage::bye());
        }
        let deadline = Instant::now() + Duration::from_secs(10);
        while !self.dispatch.all_done() && Instant::now() < deadline {
            self.dispatch.mark_all();
            std::thread::sleep(Duration::from_millis(2));
        }
        self.dispatch.stop();
        if let Some(h) = self.dispatcher.lock().unwrap().take() {
            let _ = h.join();
        }
        let cells: Vec<_> = {
            let mut map = self.dispatch.cells.lock().unwrap();
            map.drain().collect()
        };
        for (_, cell) in cells {
            let mut cell = cell.lock().unwrap();
            if !cell.done {
                cell.mux.kill();
                finish_cell(&mut cell);
            }
        }
    }
}

/// The fleet's liveness sweep, as a reactor timer-wheel task: reads
/// each connection's last heartbeat off the mux into the registry,
/// demotes against the configured deadlines, and (via the dispatcher —
/// never blocking the reactor thread) fires the membership callback on
/// epoch changes. Holds only a `Weak` fleet reference, so it cancels
/// itself once the fleet is gone.
fn start_sweep(fleet: &Arc<Fleet>) {
    let weak: Weak<Fleet> = Arc::downgrade(fleet);
    let stop = fleet.sweep_stop.clone();
    let suspect = Duration::from_secs_f64(fleet.cfg.suspect_after_s);
    let gone = Duration::from_secs_f64(fleet.cfg.gone_after_s);
    let period = Duration::from_secs_f64(
        (fleet.cfg.heartbeat_interval_s.min(fleet.cfg.suspect_after_s) / 2.0).max(0.02),
    );
    let mut last_epoch = u64::MAX;
    let id = reactor::global().add_interval(
        period,
        Box::new(move || {
            if stop.load(Ordering::Relaxed) {
                return false;
            }
            let Some(fleet) = weak.upgrade() else { return false };
            {
                let conns = fleet.conns.read().unwrap();
                for (idx, conn) in conns.iter().enumerate() {
                    // a dead transport's stale heartbeat is not liveness
                    // evidence — never let it resurrect a just-killed
                    // client
                    if conn.mux.is_dead() {
                        fleet.registry.suspect(idx);
                    } else if let Some(at) = conn.mux.last_heartbeat() {
                        fleet.registry.heard(idx, at);
                    }
                }
            }
            let epoch = fleet.registry.sweep(suspect, gone);
            if epoch != last_epoch {
                last_epoch = epoch;
                fleet.dispatch.request_kick();
            }
            true
        }),
    );
    *fleet.sweep_timer.lock().unwrap() = Some(id);
}

impl FleetConn {
    fn new(spec: &ClientSpec, mux: MuxConn) -> FleetConn {
        let control = Messenger::new(Box::new(mux.handle(0)), 4096, 0);
        FleetConn {
            name: spec.name.clone(),
            spec: spec.clone(),
            mux,
            control: Mutex::new(control),
        }
    }
}

/// Stand up the client side of one fleet connection: build its runtime
/// cell, start its heartbeat on the reactor's timer wheel, and hook the
/// connection's delivery callback into the dispatcher's dirty set. No
/// thread is spawned — the client costs a map entry until a job opens.
fn deploy_client(
    dispatch: &Arc<Dispatch>,
    spec: &ClientSpec,
    index: usize,
    mux: MuxConn,
    directory: Arc<JobDirectory>,
    heartbeat: Duration,
) {
    let runtime = MultiJobRuntime::new(&spec.name, index, mux.clone(), directory, heartbeat);
    runtime.start_heartbeat();
    let control = runtime.control_messenger();
    let cell = Arc::new(Mutex::new(ClientCell {
        runtime,
        control,
        mux: mux.clone(),
        loops: Vec::new(),
        done: false,
    }));
    dispatch.cells.lock().unwrap().insert(index, cell);
    // Weak: the callback lives inside the mux, which the cell map owns —
    // a strong Arc here would cycle dispatch → cell → mux → dispatch.
    let weak = Arc::downgrade(dispatch);
    mux.set_on_deliver(Some(Box::new(move |job| {
        if job == 0 {
            if let Some(d) = weak.upgrade() {
                d.mark(index);
            }
        }
    })));
    // catch anything delivered before the callback was installed
    dispatch.mark(index);
}

/// Run a job to completion inside this process. The controller's own
/// fields (history, best model, ...) carry the results.
///
/// Thin wrapper since the session-layer refactor: connects a one-job
/// fleet of the job's clients, submits the job over it (as job id 1,
/// frames v3-tagged like any scheduled job), and tears the fleet down —
/// so the single-job entry point exercises exactly the multiplexed
/// serving path.
pub fn run_job<C: crate::coordinator::Controller + ?Sized>(
    job: &JobConfig,
    kind: DriverKind,
    controller: &mut C,
    make_executor: &mut ExecutorFactory,
    results_dir: &str,
) -> Result<RunReport> {
    let fleet = Fleet::connect(&job.clients, kind, &job.stream)?;
    let result =
        crate::coordinator::run_one_job(&fleet, 1, job, controller, make_executor, results_dir);
    fleet.shutdown();
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::FedAvg;
    use crate::executor::{Executor, StreamTestExecutor};
    use anyhow::anyhow;

    fn results_dir() -> String {
        let d = std::env::temp_dir().join("fedflare_sim_tests");
        let _ = std::fs::create_dir_all(&d);
        d.to_string_lossy().to_string()
    }

    /// FedAvg over the add-delta workload: after R rounds with all clients
    /// adding d, the global model is exactly initial + R*d (weights sum
    /// to 1 each round).
    fn add_delta_fedavg(kind: DriverKind, chunk: usize) {
        let mut job = crate::config::JobConfig::named("sim_add", "none");
        job.rounds = 3;
        job.min_clients = 2;
        job.stream.chunk_bytes = chunk;
        let initial = StreamTestExecutor::build_model(4, 1000, 1.0);
        let mut ctl = FedAvg::new(initial, job.rounds, job.min_clients);
        ctl.task_name = "stream_test".into();
        let mut factory: Box<ExecutorFactory> = Box::new(|_i, _s| {
            Ok(Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>)
        });
        run_job(&job, kind, &mut ctl, &mut factory, &results_dir()).unwrap();
        let v = ctl.model.get("key_000").unwrap().as_f32().unwrap();
        assert!(
            v.iter().all(|&x| (x - 2.5).abs() < 1e-5),
            "expected 1.0 + 3*0.5, got {}",
            v[0]
        );
        assert_eq!(ctl.history.len(), 3);
    }

    #[test]
    fn fedavg_add_delta_inproc() {
        add_delta_fedavg(DriverKind::InProc, 1024);
    }

    #[test]
    fn fedavg_add_delta_tcp() {
        add_delta_fedavg(DriverKind::Tcp, 1024);
    }

    #[test]
    fn driver_swap_changes_nothing_above_sfm() {
        // the paper's SFM claim: same job, same numbers, different driver
        let run = |kind| {
            let mut job = crate::config::JobConfig::named("sim_swap", "none");
            job.rounds = 2;
            let initial = StreamTestExecutor::build_model(2, 100, 0.0);
            let mut ctl = FedAvg::new(initial, 2, 2);
            ctl.task_name = "stream_test".into();
            let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
                Ok(Box::new(StreamTestExecutor::new(None, 0.25)) as Box<dyn Executor>)
            });
            run_job(&job, kind, &mut ctl, &mut f, &results_dir()).unwrap();
            ctl.model
        };
        let a = run(DriverKind::InProc);
        let b = run(DriverKind::Tcp);
        assert_eq!(a, b);
    }

    /// A hierarchical job over `kind`: n clients, branching b, every
    /// client adding delta — the tree must converge to the flat oracle.
    fn add_delta_tree(kind: DriverKind, n: usize, b: usize) {
        let mut job = crate::config::JobConfig::named(&format!("sim_tree_{n}_{b}"), "none");
        job.rounds = 2;
        job.branching = b;
        job.clients = (0..n)
            .map(|i| ClientSpec {
                name: format!("site-{:02}", i + 1),
                bandwidth_bps: 0,
                partition: i,
            })
            .collect();
        let n_mid = n.div_ceil(b);
        job.min_clients = n_mid;
        let initial = StreamTestExecutor::build_model(3, 500, 1.0);
        let mut ctl = FedAvg::new(initial, job.rounds, n_mid);
        ctl.task_name = "stream_test".into();
        let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
            Ok(Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>)
        });
        run_job(&job, kind, &mut ctl, &mut f, &results_dir()).unwrap();
        let v = ctl.model.get("key_000").unwrap().as_f32().unwrap();
        assert!(
            v.iter().all(|&x| (x - 2.0).abs() < 1e-5),
            "expected 1.0 + 2*0.5, got {}",
            v[0]
        );
        assert_eq!(ctl.history.len(), 2);
        // the root gathered partials from every mid-tier node
        assert_eq!(ctl.history[0].per_client.len(), n_mid);
        assert!(ctl.history[0].per_client[0].0.starts_with("agg-"));
    }

    #[test]
    fn hierarchical_tree_matches_flat_oracle_inproc() {
        add_delta_tree(DriverKind::InProc, 9, 3);
    }

    #[test]
    fn hierarchical_tree_matches_flat_oracle_tcp() {
        add_delta_tree(DriverKind::Tcp, 8, 3);
    }

    #[test]
    fn tree_with_uneven_shards_weights_partials_correctly() {
        // 5 clients, branching 2 -> shards of 2/2/1. Client i adds
        // delta_i = 0.1*(i+1) with weight 1 each; the global mean is the
        // plain average of deltas — partial weighting must reproduce it.
        let n = 5;
        let mut job = crate::config::JobConfig::named("sim_tree_uneven", "none");
        job.rounds = 1;
        job.branching = 2;
        job.clients = (0..n)
            .map(|i| ClientSpec {
                name: format!("site-{:02}", i + 1),
                bandwidth_bps: 0,
                partition: i,
            })
            .collect();
        job.min_clients = 3;
        let initial = StreamTestExecutor::build_model(2, 200, 1.0);
        let mut ctl = FedAvg::new(initial, 1, 3);
        ctl.task_name = "stream_test".into();
        let mut f: Box<ExecutorFactory> = Box::new(|i, _s| {
            Ok(Box::new(StreamTestExecutor::new(None, 0.1 * (i + 1) as f32))
                as Box<dyn Executor>)
        });
        run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
        let v = ctl.model.get("key_000").unwrap().as_f32().unwrap();
        let oracle = 1.0 + (0.1 + 0.2 + 0.3 + 0.4 + 0.5) / 5.0;
        assert!(
            v.iter().all(|&x| (x - oracle).abs() < 1e-5),
            "expected {oracle}, got {}",
            v[0]
        );
    }

    #[test]
    fn tree_shard_straggler_is_dropped_at_the_mid_tier() {
        // 9 clients, branching 3; the last leaf stalls ~800 ms per task
        // (and would shift the mean by +100 if folded) while the job's
        // straggler timeout is 250 ms. The timeout is threaded down to
        // the shard gathers, so only the stalled leaf's contribution is
        // lost: its shard forwards a reduced-weight partial, every
        // subtree reports, and the aggregate stays on the fast-leaf
        // oracle.
        let n = 9;
        let mut job = crate::config::JobConfig::named("sim_tree_straggler", "none");
        job.rounds = 1;
        job.branching = 3;
        job.round_timeout_s = Some(0.25);
        job.clients = (0..n)
            .map(|i| ClientSpec {
                name: format!("site-{:02}", i + 1),
                bandwidth_bps: 0,
                partition: i,
            })
            .collect();
        job.min_clients = 3;
        let initial = StreamTestExecutor::build_model(2, 200, 1.0);
        let mut ctl = FedAvg::new(initial, 1, 3);
        ctl.task_name = "stream_test".into();
        let mut f: Box<ExecutorFactory> = Box::new(|i, _s| {
            Ok(if i == n - 1 {
                let mut e = StreamTestExecutor::new(None, 100.0);
                e.work_ms = 400;
                Box::new(e) as Box<dyn Executor>
            } else {
                Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>
            })
        });
        run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
        // all 3 subtrees reported a partial
        assert_eq!(ctl.history[0].per_client.len(), 3);
        // weights: shards fold 3 + 3 + 2 fast leaves, all at value 1.5
        let v = ctl.model.get("key_000").unwrap().as_f32().unwrap();
        assert!(
            v.iter().all(|&x| (x - 1.5).abs() < 1e-5),
            "stalled leaf leaked into the aggregate: {}",
            v[0]
        );
        let folded: f64 = ctl.history[0].per_client.iter().map(|(.., w)| w).sum();
        assert!((folded - 8.0).abs() < 1e-9, "expected 8 leaves folded: {folded}");
    }

    #[test]
    fn throttled_client_still_completes() {
        let mut job = crate::config::JobConfig::named("sim_throttle", "none");
        job.rounds = 1;
        job.stream.chunk_bytes = 4096;
        // site-2 at 2 MB/s with a ~80 kB model: measurable but quick
        job.clients[1].bandwidth_bps = 2_000_000;
        let initial = StreamTestExecutor::build_model(2, 10_000, 0.0);
        let mut ctl = FedAvg::new(initial, 1, 2);
        ctl.task_name = "stream_test".into();
        let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
            Ok(Box::new(StreamTestExecutor::new(None, 1.0)) as Box<dyn Executor>)
        });
        run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
        let v = ctl.model.get("key_000").unwrap().as_f32().unwrap();
        assert!((v[0] - 1.0).abs() < 1e-6);
    }

    /// Controller that records the order (and spacing) in which client
    /// results complete the streaming gather.
    struct OrderProbe {
        model: crate::tensor::TensorDict,
        order: Vec<String>,
        arrivals: Vec<std::time::Instant>,
    }

    impl crate::coordinator::Controller for OrderProbe {
        fn name(&self) -> &'static str {
            "order_probe"
        }
        fn run(
            &mut self,
            comm: &mut crate::coordinator::Communicator,
            _ctx: &mut crate::coordinator::ServerCtx,
        ) -> anyhow::Result<()> {
            let targets: Vec<usize> = (0..comm.n_clients()).collect();
            let task = FlMessage::task("stream_test", 0, self.model.clone());
            let (order, arrivals) = comm.broadcast_and_reduce(
                &task,
                &targets,
                (Vec::new(), Vec::new()),
                |(mut order, mut arrivals): (Vec<String>, Vec<_>), r| {
                    order.push(r.client.clone());
                    arrivals.push(std::time::Instant::now());
                    Ok((order, arrivals))
                },
            )?;
            self.order = order;
            self.arrivals = arrivals;
            comm.shutdown();
            Ok(())
        }
    }

    #[test]
    fn fast_client_is_folded_before_slow_client_arrives() {
        // site-2's whole connection is throttled to 8 MB/s on a 4 MB
        // model (shared-link token bucket, 1 MB burst), so its round trip
        // takes ~0.75 s while site-1 finishes in milliseconds; the
        // streaming gather must hand site-1's result to the fold while
        // site-2 is still mid-transfer.
        let mut job = crate::config::JobConfig::named("sim_order", "none");
        job.rounds = 1;
        job.stream.chunk_bytes = 64 << 10;
        job.clients[1].bandwidth_bps = 8_000_000;
        let mut ctl = OrderProbe {
            model: StreamTestExecutor::build_model(4, 262_144, 1.0),
            order: Vec::new(),
            arrivals: Vec::new(),
        };
        let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
            Ok(Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>)
        });
        run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
        assert_eq!(
            ctl.order,
            vec!["site-1".to_string(), "site-2".to_string()],
            "fast client must complete the gather first"
        );
        // the fold of the fast result happened well before the slow one
        // arrived (throttling stretches the gap to ~1 s; demand 200 ms)
        let gap = ctl.arrivals[1].duration_since(ctl.arrivals[0]);
        assert!(
            gap > std::time::Duration::from_millis(200),
            "no overlap between fold and slow transfer: gap {gap:?}"
        );
    }

    /// An executor that fails — the job must surface the error.
    struct Failing;
    impl Executor for Failing {
        fn execute(&mut self, _t: &FlMessage) -> Result<FlMessage> {
            Err(anyhow!("injected failure"))
        }
    }

    #[test]
    fn client_failure_propagates() {
        let mut job = crate::config::JobConfig::named("sim_fail", "none");
        job.rounds = 1;
        let initial = StreamTestExecutor::build_model(1, 10, 0.0);
        let mut ctl = FedAvg::new(initial, 1, 2);
        ctl.task_name = "stream_test".into();
        let mut f: Box<ExecutorFactory> =
            Box::new(|_i, _s| Ok(Box::new(Failing) as Box<dyn Executor>));
        let err = run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir());
        assert!(err.is_err());
    }

    #[test]
    fn filters_compose_with_fedavg() {
        // secure-agg masks must cancel in the FedAvg sum: same result as
        // without the filter
        let base = {
            let mut job = crate::config::JobConfig::named("sim_nofilter", "none");
            job.rounds = 2;
            let mut ctl = FedAvg::new(StreamTestExecutor::build_model(2, 50, 1.0), 2, 2);
            ctl.task_name = "stream_test".into();
            let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
                Ok(Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>)
            });
            run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
            ctl.model
        };
        let masked = {
            let mut job = crate::config::JobConfig::named("sim_secureagg", "none");
            job.rounds = 2;
            job.filters = vec![crate::config::FilterSpec::SecureAgg { seed: 5 }];
            let mut ctl = FedAvg::new(StreamTestExecutor::build_model(2, 50, 1.0), 2, 2);
            ctl.task_name = "stream_test".into();
            let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
                Ok(Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>)
            });
            run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
            ctl.model
        };
        // equal-weight (n_samples=1 each) FedAvg: masks cancel
        assert!(base.max_abs_diff(&masked) < 1e-4, "{}", base.max_abs_diff(&masked));
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join("fedflare_sim_tests"));
    }
}
