//! Multi-client simulation harness: runs a full FL job — server controller
//! plus N client task loops — in one process, over either the in-process
//! channel driver or real TCP loopback connections, with optional
//! per-client bandwidth throttling (the paper's fast/slow-site asymmetry).
//!
//! This is the engine behind `fedflare repro *`, the examples, and the
//! integration tests. Multi-process deployment (`fedflare server` /
//! `fedflare client`) shares all the same code paths; only connection
//! setup differs (see `main.rs`).

use anyhow::{anyhow, Context, Result};

use crate::config::{ClientSpec, JobConfig};
use crate::coordinator::{accept_registration, ClientHandle, Communicator, Controller, ServerCtx};
use crate::executor::{ClientRuntime, Executor};
use crate::filters::build_chain;
use crate::metrics::MetricsSink;
use crate::sfm::{inproc, tcp, throttle::Throttled, Driver};
use crate::streaming::Messenger;

/// Which transport the simulation uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DriverKind {
    /// Bounded in-process channels.
    InProc,
    /// Real TCP connections over loopback.
    Tcp,
}

/// Build the per-client executor (index, spec) -> Executor.
pub type ExecutorFactory<'a> = dyn FnMut(usize, &ClientSpec) -> Result<Box<dyn Executor>> + 'a;

/// Run a job to completion inside this process. The controller's own
/// fields (history, best model, ...) carry the results.
pub fn run_job(
    job: &JobConfig,
    kind: DriverKind,
    controller: &mut dyn Controller,
    make_executor: &mut ExecutorFactory,
    results_dir: &str,
) -> Result<()> {
    let sink = MetricsSink::create(results_dir, &job.name)?;
    let mut ctx = ServerCtx::new(sink, &job.name);
    let chunk = job.stream.chunk_bytes;
    let window = job.stream.window;
    let verify = job.stream.verify_crc;

    // --- build transport pairs + client runtimes
    let mut client_threads = Vec::new();
    let mut server_messengers: Vec<Messenger> = Vec::new();

    match kind {
        DriverKind::InProc => {
            for (i, spec) in job.clients.iter().enumerate() {
                let (sa, ca) = inproc::pair(window, &spec.name);
                let client_driver: Box<dyn Driver> = wrap_throttle(Box::new(ca), spec);
                let server_driver: Box<dyn Driver> = wrap_throttle(Box::new(sa), spec);
                server_messengers.push(Messenger::new(server_driver, chunk, 0));
                let messenger = Messenger::new(client_driver, chunk, (i + 1) as u32);
                client_threads.push(spawn_client(job, i, spec, messenger, make_executor)?);
            }
        }
        DriverKind::Tcp => {
            let listener = tcp::bind("127.0.0.1:0")?;
            let addr = listener.local_addr().context("local addr")?;
            for (i, spec) in job.clients.iter().enumerate() {
                let drv = tcp::TcpDriver::connect(addr, verify)?;
                let client_driver: Box<dyn Driver> = wrap_throttle(Box::new(drv), spec);
                let messenger = Messenger::new(client_driver, chunk, (i + 1) as u32);
                client_threads.push(spawn_client(job, i, spec, messenger, make_executor)?);
                let (conn, _) = listener.accept().context("accept")?;
                let sdrv = tcp::TcpDriver::from_stream(conn, verify)?;
                // server->client direction throttled too (a slow link is
                // slow both ways)
                let server_driver: Box<dyn Driver> = wrap_throttle(Box::new(sdrv), spec);
                server_messengers.push(Messenger::new(server_driver, chunk, 0));
            }
        }
    }

    // --- registration handshake, then per-client IO workers
    let mut handles = Vec::new();
    for mut m in server_messengers {
        let name = accept_registration(&mut m)?;
        handles.push(ClientHandle::spawn(name, m));
    }
    // order handles to match job.clients order (TCP accepts may race)
    handles.sort_by_key(|h| {
        job.clients
            .iter()
            .position(|c| c.name == h.name)
            .unwrap_or(usize::MAX)
    });
    let mut comm = Communicator::new(handles, job.seed);

    // --- run the workflow
    let run_result = controller.run(&mut comm, &mut ctx);

    // tear the transport down even when the controller failed mid-round,
    // so idle clients observe a bye (or a closed channel) instead of
    // blocking on their next task while we join them below
    if run_result.is_err() {
        comm.shutdown();
    }
    drop(comm);

    // --- join clients
    let mut client_errs = Vec::new();
    for (name, t) in client_threads {
        match t.join() {
            Ok(Ok(_tasks)) => {}
            Ok(Err(e)) => client_errs.push(format!("{name}: {e}")),
            Err(_) => client_errs.push(format!("{name}: panicked")),
        }
    }
    run_result?;
    if !client_errs.is_empty() {
        return Err(anyhow!("client failures: {}", client_errs.join("; ")));
    }
    Ok(())
}

fn wrap_throttle(driver: Box<dyn Driver>, spec: &ClientSpec) -> Box<dyn Driver> {
    if spec.bandwidth_bps > 0 {
        Box::new(Throttled::new(
            BoxedDriver(driver),
            spec.bandwidth_bps,
            crate::DEFAULT_CHUNK_BYTES as u64,
        ))
    } else {
        driver
    }
}

/// Adapter: `Box<dyn Driver>` itself as a Driver (for the Throttled
/// decorator, which is generic).
struct BoxedDriver(Box<dyn Driver>);

impl Driver for BoxedDriver {
    fn send(&mut self, frame: crate::sfm::Frame) -> Result<(), crate::sfm::SfmError> {
        self.0.send(frame)
    }
    fn recv(&mut self) -> Result<crate::sfm::Frame, crate::sfm::SfmError> {
        self.0.recv()
    }
    fn name(&self) -> String {
        self.0.name()
    }
}

type ClientThread = (String, std::thread::JoinHandle<Result<usize>>);

fn spawn_client(
    job: &JobConfig,
    idx: usize,
    spec: &ClientSpec,
    messenger: Messenger,
    make_executor: &mut ExecutorFactory,
) -> Result<ClientThread> {
    let executor = make_executor(idx, spec)?;
    let filters = build_chain(&job.filters, idx, job.clients.len());
    let name = spec.name.clone();
    let tname = name.clone();
    let handle = std::thread::Builder::new()
        .name(format!("client-{name}"))
        .spawn(move || {
            let mut rt = ClientRuntime::new(&tname, messenger, executor, filters);
            rt.run_loop()
        })
        .context("spawn client thread")?;
    Ok((name, handle))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::FedAvg;
    use crate::executor::StreamTestExecutor;
    use crate::message::FlMessage;
    use crate::util::json::Json;

    fn results_dir() -> String {
        let d = std::env::temp_dir().join("fedflare_sim_tests");
        let _ = std::fs::create_dir_all(&d);
        d.to_string_lossy().to_string()
    }

    /// FedAvg over the add-delta workload: after R rounds with all clients
    /// adding d, the global model is exactly initial + R*d (weights sum
    /// to 1 each round).
    fn add_delta_fedavg(kind: DriverKind, chunk: usize) {
        let mut job = crate::config::JobConfig::named("sim_add", "none");
        job.rounds = 3;
        job.min_clients = 2;
        job.stream.chunk_bytes = chunk;
        let initial = StreamTestExecutor::build_model(4, 1000, 1.0);
        let mut ctl = FedAvg::new(initial, job.rounds, job.min_clients);
        ctl.task_name = "stream_test".into();
        let mut factory: Box<ExecutorFactory> = Box::new(|_i, _s| {
            Ok(Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>)
        });
        run_job(&job, kind, &mut ctl, &mut factory, &results_dir()).unwrap();
        let v = ctl.model.get("key_000").unwrap().as_f32().unwrap();
        assert!(
            v.iter().all(|&x| (x - 2.5).abs() < 1e-5),
            "expected 1.0 + 3*0.5, got {}",
            v[0]
        );
        assert_eq!(ctl.history.len(), 3);
    }

    #[test]
    fn fedavg_add_delta_inproc() {
        add_delta_fedavg(DriverKind::InProc, 1024);
    }

    #[test]
    fn fedavg_add_delta_tcp() {
        add_delta_fedavg(DriverKind::Tcp, 1024);
    }

    #[test]
    fn driver_swap_changes_nothing_above_sfm() {
        // the paper's SFM claim: same job, same numbers, different driver
        let run = |kind| {
            let mut job = crate::config::JobConfig::named("sim_swap", "none");
            job.rounds = 2;
            let initial = StreamTestExecutor::build_model(2, 100, 0.0);
            let mut ctl = FedAvg::new(initial, 2, 2);
            ctl.task_name = "stream_test".into();
            let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
                Ok(Box::new(StreamTestExecutor::new(None, 0.25)) as Box<dyn Executor>)
            });
            run_job(&job, kind, &mut ctl, &mut f, &results_dir()).unwrap();
            ctl.model
        };
        let a = run(DriverKind::InProc);
        let b = run(DriverKind::Tcp);
        assert_eq!(a, b);
    }

    #[test]
    fn throttled_client_still_completes() {
        let mut job = crate::config::JobConfig::named("sim_throttle", "none");
        job.rounds = 1;
        job.stream.chunk_bytes = 4096;
        // site-2 at 2 MB/s with a ~80 kB model: measurable but quick
        job.clients[1].bandwidth_bps = 2_000_000;
        let initial = StreamTestExecutor::build_model(2, 10_000, 0.0);
        let mut ctl = FedAvg::new(initial, 1, 2);
        ctl.task_name = "stream_test".into();
        let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
            Ok(Box::new(StreamTestExecutor::new(None, 1.0)) as Box<dyn Executor>)
        });
        run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
        let v = ctl.model.get("key_000").unwrap().as_f32().unwrap();
        assert!((v[0] - 1.0).abs() < 1e-6);
    }

    /// Controller that records the order (and spacing) in which client
    /// results complete the streaming gather.
    struct OrderProbe {
        model: crate::tensor::TensorDict,
        order: Vec<String>,
        arrivals: Vec<std::time::Instant>,
    }

    impl crate::coordinator::Controller for OrderProbe {
        fn name(&self) -> &'static str {
            "order_probe"
        }
        fn run(
            &mut self,
            comm: &mut crate::coordinator::Communicator,
            _ctx: &mut crate::coordinator::ServerCtx,
        ) -> anyhow::Result<()> {
            let targets: Vec<usize> = (0..comm.n_clients()).collect();
            let task = FlMessage::task("stream_test", 0, self.model.clone());
            let (order, arrivals) = comm.broadcast_and_reduce(
                &task,
                &targets,
                (Vec::new(), Vec::new()),
                |(mut order, mut arrivals): (Vec<String>, Vec<_>), r| {
                    order.push(r.client.clone());
                    arrivals.push(std::time::Instant::now());
                    Ok((order, arrivals))
                },
            )?;
            self.order = order;
            self.arrivals = arrivals;
            comm.shutdown();
            Ok(())
        }
    }

    #[test]
    fn fast_client_is_folded_before_slow_client_arrives() {
        // site-2 is throttled to 8 MB/s on a 4 MB model (both directions;
        // the token bucket's 1 MB burst covers only the first chunk-span),
        // so its round trip takes ~0.75 s while site-1 finishes in
        // milliseconds; the streaming gather must hand site-1's result to
        // the fold while site-2 is still mid-transfer.
        let mut job = crate::config::JobConfig::named("sim_order", "none");
        job.rounds = 1;
        job.stream.chunk_bytes = 64 << 10;
        job.clients[1].bandwidth_bps = 8_000_000;
        let mut ctl = OrderProbe {
            model: StreamTestExecutor::build_model(4, 262_144, 1.0),
            order: Vec::new(),
            arrivals: Vec::new(),
        };
        let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
            Ok(Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>)
        });
        run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
        assert_eq!(
            ctl.order,
            vec!["site-1".to_string(), "site-2".to_string()],
            "fast client must complete the gather first"
        );
        // the fold of the fast result happened well before the slow one
        // arrived (throttling stretches the gap to ~1 s; demand 200 ms)
        let gap = ctl.arrivals[1].duration_since(ctl.arrivals[0]);
        assert!(
            gap > std::time::Duration::from_millis(200),
            "no overlap between fold and slow transfer: gap {gap:?}"
        );
    }

    /// An executor that fails — the job must surface the error.
    struct Failing;
    impl Executor for Failing {
        fn execute(&mut self, _t: &FlMessage) -> Result<FlMessage> {
            Err(anyhow!("injected failure"))
        }
    }

    #[test]
    fn client_failure_propagates() {
        let mut job = crate::config::JobConfig::named("sim_fail", "none");
        job.rounds = 1;
        let initial = StreamTestExecutor::build_model(1, 10, 0.0);
        let mut ctl = FedAvg::new(initial, 1, 2);
        ctl.task_name = "stream_test".into();
        let mut f: Box<ExecutorFactory> =
            Box::new(|_i, _s| Ok(Box::new(Failing) as Box<dyn Executor>));
        let err = run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir());
        assert!(err.is_err());
    }

    #[test]
    fn filters_compose_with_fedavg() {
        // secure-agg masks must cancel in the FedAvg sum: same result as
        // without the filter
        let base = {
            let mut job = crate::config::JobConfig::named("sim_nofilter", "none");
            job.rounds = 2;
            let mut ctl = FedAvg::new(StreamTestExecutor::build_model(2, 50, 1.0), 2, 2);
            ctl.task_name = "stream_test".into();
            let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
                Ok(Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>)
            });
            run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
            ctl.model
        };
        let masked = {
            let mut job = crate::config::JobConfig::named("sim_secureagg", "none");
            job.rounds = 2;
            job.filters = vec![crate::config::FilterSpec::SecureAgg { seed: 5 }];
            let mut ctl = FedAvg::new(StreamTestExecutor::build_model(2, 50, 1.0), 2, 2);
            ctl.task_name = "stream_test".into();
            let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
                Ok(Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>)
            });
            run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
            ctl.model
        };
        // equal-weight (n_samples=1 each) FedAvg: masks cancel
        assert!(base.max_abs_diff(&masked) < 1e-4, "{}", base.max_abs_diff(&masked));
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join("fedflare_sim_tests"));
    }
}
