//! The in-process federation harness: a persistent multiplexed client
//! [`Fleet`] plus the single-job convenience wrapper [`run_job`].
//!
//! Since the session-layer refactor, the fleet — not the job — owns the
//! transports: each client holds **one** connection (in-process channels
//! or real TCP loopback), wrapped in the session mux
//! ([`crate::sfm::mux`]), and every FL job runs over its own multiplexed
//! channel of those shared connections. Per-client bandwidth throttling
//! applies to the connection as a whole (one token bucket per link), so
//! concurrent jobs share a slow site's budget instead of each minting
//! their own. Client processes are modeled by
//! [`MultiJobRuntime`](crate::executor::MultiJobRuntime) threads: one per
//! connection, servicing `job_open`/`job_abort` control messages and
//! running one task loop (with its own executor) per active job.
//!
//! [`run_job`] is now a thin wrapper: connect a fleet of the job's
//! clients, run the job over it
//! ([`run_one_job`](crate::coordinator::run_one_job)), shut the fleet
//! down. Multi-job serving — `submit`/`status`/`abort`, `max_concurrent`
//! — lives in [`crate::coordinator::JobScheduler`] (see `fedflare serve`).
//!
//! With `job.branching = B > 1` (and more than B clients) a job builds a
//! **2-level aggregator tree**: ⌈N/B⌉ mid-tier nodes each fold a shard of
//! leaves over the shared fleet connections and forward one job-tagged
//! partial per round on a dedicated link — same wire format, same
//! streaming folds.
//!
//! This is the engine behind `fedflare repro *`, the examples, and the
//! integration tests. Multi-process deployment (`fedflare server` /
//! `fedflare client`) shares the same per-job code paths over dedicated
//! (unmuxed) connections.

use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::config::{ClientSpec, JobConfig, StreamConfig};
use crate::executor::{JobDirectory, MultiJobRuntime};
use crate::message::FlMessage;
use crate::sfm::mux::{JobTagged, MuxConn};
use crate::sfm::{inproc, tcp, Driver, EvictionPolicy};
use crate::streaming::Messenger;
use crate::tensor::TensorDict;
use crate::util::json::Json;

/// Which transport the simulation uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DriverKind {
    /// Bounded in-process channels.
    InProc,
    /// Real TCP connections over loopback.
    Tcp,
}

/// Build the per-client executor (index, spec) -> Executor.
pub type ExecutorFactory<'a> =
    dyn FnMut(usize, &ClientSpec) -> Result<Box<dyn crate::executor::Executor>> + 'a;

/// What a finished job reports back beyond the controller's own fields.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    /// Peak decoded in-flight gather bytes at the **root** communicator
    /// (per-node counter — mid-tier folds are excluded, unlike the
    /// process-global [`crate::util::mem::gather_peak`]).
    pub root_gather_peak: u64,
}

/// One server-side fleet connection: the shared mux plus the control
/// channel (job 0) the scheduler announces jobs on.
struct FleetConn {
    name: String,
    mux: MuxConn,
    control: Mutex<Messenger>,
}

/// A fleet client-runtime thread, by client name.
type FleetClientThread = (String, std::thread::JoinHandle<Result<()>>);

/// A connected, persistent client fleet (see module docs): the shared
/// transports jobs multiplex over, the in-process [`JobDirectory`], and
/// the client-runtime threads standing in for client processes.
pub struct Fleet {
    conns: Vec<FleetConn>,
    kind: DriverKind,
    window: usize,
    verify: bool,
    directory: Arc<JobDirectory>,
    client_threads: Mutex<Vec<FleetClientThread>>,
}

impl Fleet {
    /// Connect one multiplexed connection + client runtime per spec.
    /// `stream` configures the fleet-level links (window, CRC); each job
    /// keeps its own chunking on top.
    pub fn connect(
        specs: &[ClientSpec],
        kind: DriverKind,
        stream: &StreamConfig,
    ) -> Result<Arc<Fleet>> {
        let directory = JobDirectory::new();
        let window = stream.window;
        let verify = stream.verify_crc;
        let burst = crate::DEFAULT_CHUNK_BYTES as u64;
        let mut conns = Vec::with_capacity(specs.len());
        let mut threads = Vec::with_capacity(specs.len());
        match kind {
            DriverKind::InProc => {
                for (i, spec) in specs.iter().enumerate() {
                    let (s, c) = inproc::pair(window, &spec.name);
                    let (sr, cr) = (s.recv_half(), c.recv_half());
                    let server_mux =
                        MuxConn::spawn(Box::new(s), Box::new(sr), spec.bandwidth_bps, burst);
                    let client_mux =
                        MuxConn::spawn(Box::new(c), Box::new(cr), spec.bandwidth_bps, burst);
                    threads.push(spawn_fleet_client(spec, i, client_mux, directory.clone())?);
                    conns.push(FleetConn::new(spec, server_mux));
                }
            }
            DriverKind::Tcp => {
                let listener = tcp::bind("127.0.0.1:0")?;
                let addr = listener.local_addr().context("local addr")?;
                for (i, spec) in specs.iter().enumerate() {
                    let cd = tcp::TcpDriver::connect(addr, verify)?;
                    let cdr = cd.try_clone()?;
                    let client_mux =
                        MuxConn::spawn(Box::new(cd), Box::new(cdr), spec.bandwidth_bps, burst);
                    threads.push(spawn_fleet_client(spec, i, client_mux, directory.clone())?);
                    let (conn, _) = listener.accept().context("accept")?;
                    let sd = tcp::TcpDriver::from_stream(conn, verify)?;
                    let sdr = sd.try_clone()?;
                    let server_mux =
                        MuxConn::spawn(Box::new(sd), Box::new(sdr), spec.bandwidth_bps, burst);
                    conns.push(FleetConn::new(spec, server_mux));
                }
            }
        }
        Ok(Arc::new(Fleet {
            conns,
            kind,
            window,
            verify,
            directory,
            client_threads: Mutex::new(threads),
        }))
    }

    pub fn n_clients(&self) -> usize {
        self.conns.len()
    }

    pub fn kind(&self) -> DriverKind {
        self.kind
    }

    /// The in-process job registry shared with the client runtimes.
    pub fn directory(&self) -> &Arc<JobDirectory> {
        &self.directory
    }

    /// Fleet connection index of a client, by name.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.conns.iter().position(|c| c.name == name)
    }

    /// A server-side messenger over client `idx`'s connection, scoped to
    /// `job` (chunking and stale-stream eviction from `stream`).
    pub fn job_messenger(&self, idx: usize, job: u32, stream: &StreamConfig) -> Messenger {
        let mut m = Messenger::new(
            Box::new(self.conns[idx].mux.handle(job)),
            stream.chunk_bytes,
            0,
        );
        if let Some(policy) = EvictionPolicy::stale_after_s(stream.stale_stream_age_s) {
            m.set_reassembly_policy(policy);
        }
        m
    }

    /// Announce `job` on client `idx`'s control channel; the client's
    /// runtime claims its start spec from the directory and spawns the
    /// job's task loop.
    pub fn open_job(&self, idx: usize, job: u32, name: &str) -> Result<()> {
        let msg = FlMessage::task("job_open", 0, TensorDict::new())
            .with_meta("job", Json::num(job as f64))
            .with_meta("job_name", Json::str(name));
        self.conns[idx]
            .control
            .lock()
            .unwrap()
            .send_msg(&msg)
            .map_err(|e| anyhow!("open job {job} on {}: {e}", self.conns[idx].name))
    }

    /// Abort `job` fleet-wide: revoke unclaimed deployments, tell every
    /// client to sever the job's channel, and sever the server-side
    /// queues — in-flight streams drain into the eviction counters
    /// ([`crate::util::mem::evicted_bytes`]) instead of stranding buffers.
    pub fn abort_job(&self, job: u32) {
        self.directory.revoke(job);
        for conn in &self.conns {
            let msg = FlMessage::task("job_abort", 0, TensorDict::new())
                .with_meta("job", Json::num(job as f64));
            let _ = conn.control.lock().unwrap().send_msg(&msg);
            conn.mux.close_job(job);
        }
    }

    /// A dedicated mid-tier link for a hierarchical job: a fresh duplex
    /// pair of the fleet's driver kind, both ends stamping `job` on their
    /// frames. Returns (root side, mid-tier side); the mid-tier side's
    /// stream tag is `tag`.
    pub fn midtier_link(
        &self,
        job: u32,
        stream: &StreamConfig,
        tag: u32,
    ) -> Result<(Messenger, Messenger)> {
        let (down, up): (Box<dyn Driver>, Box<dyn Driver>) = match self.kind {
            DriverKind::InProc => {
                let (a, b) = inproc::pair(self.window, &format!("mid-{job}-{tag}"));
                (Box::new(a), Box::new(b))
            }
            DriverKind::Tcp => {
                let listener = tcp::bind("127.0.0.1:0")?;
                let addr = listener.local_addr().context("midtier addr")?;
                let up = tcp::TcpDriver::connect(addr, self.verify)?;
                let (conn, _) = listener.accept().context("accept midtier")?;
                let down = tcp::TcpDriver::from_stream(conn, self.verify)?;
                (Box::new(down), Box::new(up))
            }
        };
        Ok((
            Messenger::new(
                Box::new(JobTagged::new(down, job)),
                stream.chunk_bytes,
                0,
            ),
            Messenger::new(Box::new(JobTagged::new(up, job)), stream.chunk_bytes, tag),
        ))
    }

    /// End the fleet: bye every control channel, then join the client
    /// runtimes (each joins its job loops first). Idempotent.
    pub fn shutdown(&self) {
        for conn in &self.conns {
            let _ = conn.control.lock().unwrap().send_msg(&FlMessage::bye());
        }
        let mut threads = self.client_threads.lock().unwrap();
        for (name, t) in threads.drain(..) {
            match t.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => log::warn!("fleet client {name}: {e}"),
                Err(_) => log::warn!("fleet client {name}: panicked"),
            }
        }
    }
}

impl FleetConn {
    fn new(spec: &ClientSpec, mux: MuxConn) -> FleetConn {
        let control = Messenger::new(Box::new(mux.handle(0)), 4096, 0);
        FleetConn {
            name: spec.name.clone(),
            mux,
            control: Mutex::new(control),
        }
    }
}

fn spawn_fleet_client(
    spec: &ClientSpec,
    index: usize,
    mux: MuxConn,
    directory: Arc<JobDirectory>,
) -> Result<FleetClientThread> {
    let name = spec.name.clone();
    let tname = name.clone();
    let handle = std::thread::Builder::new()
        .name(format!("fleet-{name}"))
        .spawn(move || MultiJobRuntime::new(&tname, index, mux, directory).run())
        .context("spawn fleet client")?;
    Ok((name, handle))
}

/// Run a job to completion inside this process. The controller's own
/// fields (history, best model, ...) carry the results.
///
/// Thin wrapper since the session-layer refactor: connects a one-job
/// fleet of the job's clients, submits the job over it (as job id 1,
/// frames v3-tagged like any scheduled job), and tears the fleet down —
/// so the single-job entry point exercises exactly the multiplexed
/// serving path.
pub fn run_job<C: crate::coordinator::Controller + ?Sized>(
    job: &JobConfig,
    kind: DriverKind,
    controller: &mut C,
    make_executor: &mut ExecutorFactory,
    results_dir: &str,
) -> Result<RunReport> {
    let fleet = Fleet::connect(&job.clients, kind, &job.stream)?;
    let result =
        crate::coordinator::run_one_job(&fleet, 1, job, controller, make_executor, results_dir);
    fleet.shutdown();
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::FedAvg;
    use crate::executor::{Executor, StreamTestExecutor};
    use anyhow::anyhow;

    fn results_dir() -> String {
        let d = std::env::temp_dir().join("fedflare_sim_tests");
        let _ = std::fs::create_dir_all(&d);
        d.to_string_lossy().to_string()
    }

    /// FedAvg over the add-delta workload: after R rounds with all clients
    /// adding d, the global model is exactly initial + R*d (weights sum
    /// to 1 each round).
    fn add_delta_fedavg(kind: DriverKind, chunk: usize) {
        let mut job = crate::config::JobConfig::named("sim_add", "none");
        job.rounds = 3;
        job.min_clients = 2;
        job.stream.chunk_bytes = chunk;
        let initial = StreamTestExecutor::build_model(4, 1000, 1.0);
        let mut ctl = FedAvg::new(initial, job.rounds, job.min_clients);
        ctl.task_name = "stream_test".into();
        let mut factory: Box<ExecutorFactory> = Box::new(|_i, _s| {
            Ok(Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>)
        });
        run_job(&job, kind, &mut ctl, &mut factory, &results_dir()).unwrap();
        let v = ctl.model.get("key_000").unwrap().as_f32().unwrap();
        assert!(
            v.iter().all(|&x| (x - 2.5).abs() < 1e-5),
            "expected 1.0 + 3*0.5, got {}",
            v[0]
        );
        assert_eq!(ctl.history.len(), 3);
    }

    #[test]
    fn fedavg_add_delta_inproc() {
        add_delta_fedavg(DriverKind::InProc, 1024);
    }

    #[test]
    fn fedavg_add_delta_tcp() {
        add_delta_fedavg(DriverKind::Tcp, 1024);
    }

    #[test]
    fn driver_swap_changes_nothing_above_sfm() {
        // the paper's SFM claim: same job, same numbers, different driver
        let run = |kind| {
            let mut job = crate::config::JobConfig::named("sim_swap", "none");
            job.rounds = 2;
            let initial = StreamTestExecutor::build_model(2, 100, 0.0);
            let mut ctl = FedAvg::new(initial, 2, 2);
            ctl.task_name = "stream_test".into();
            let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
                Ok(Box::new(StreamTestExecutor::new(None, 0.25)) as Box<dyn Executor>)
            });
            run_job(&job, kind, &mut ctl, &mut f, &results_dir()).unwrap();
            ctl.model
        };
        let a = run(DriverKind::InProc);
        let b = run(DriverKind::Tcp);
        assert_eq!(a, b);
    }

    /// A hierarchical job over `kind`: n clients, branching b, every
    /// client adding delta — the tree must converge to the flat oracle.
    fn add_delta_tree(kind: DriverKind, n: usize, b: usize) {
        let mut job = crate::config::JobConfig::named(&format!("sim_tree_{n}_{b}"), "none");
        job.rounds = 2;
        job.branching = b;
        job.clients = (0..n)
            .map(|i| ClientSpec {
                name: format!("site-{:02}", i + 1),
                bandwidth_bps: 0,
                partition: i,
            })
            .collect();
        let n_mid = n.div_ceil(b);
        job.min_clients = n_mid;
        let initial = StreamTestExecutor::build_model(3, 500, 1.0);
        let mut ctl = FedAvg::new(initial, job.rounds, n_mid);
        ctl.task_name = "stream_test".into();
        let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
            Ok(Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>)
        });
        run_job(&job, kind, &mut ctl, &mut f, &results_dir()).unwrap();
        let v = ctl.model.get("key_000").unwrap().as_f32().unwrap();
        assert!(
            v.iter().all(|&x| (x - 2.0).abs() < 1e-5),
            "expected 1.0 + 2*0.5, got {}",
            v[0]
        );
        assert_eq!(ctl.history.len(), 2);
        // the root gathered partials from every mid-tier node
        assert_eq!(ctl.history[0].per_client.len(), n_mid);
        assert!(ctl.history[0].per_client[0].0.starts_with("agg-"));
    }

    #[test]
    fn hierarchical_tree_matches_flat_oracle_inproc() {
        add_delta_tree(DriverKind::InProc, 9, 3);
    }

    #[test]
    fn hierarchical_tree_matches_flat_oracle_tcp() {
        add_delta_tree(DriverKind::Tcp, 8, 3);
    }

    #[test]
    fn tree_with_uneven_shards_weights_partials_correctly() {
        // 5 clients, branching 2 -> shards of 2/2/1. Client i adds
        // delta_i = 0.1*(i+1) with weight 1 each; the global mean is the
        // plain average of deltas — partial weighting must reproduce it.
        let n = 5;
        let mut job = crate::config::JobConfig::named("sim_tree_uneven", "none");
        job.rounds = 1;
        job.branching = 2;
        job.clients = (0..n)
            .map(|i| ClientSpec {
                name: format!("site-{:02}", i + 1),
                bandwidth_bps: 0,
                partition: i,
            })
            .collect();
        job.min_clients = 3;
        let initial = StreamTestExecutor::build_model(2, 200, 1.0);
        let mut ctl = FedAvg::new(initial, 1, 3);
        ctl.task_name = "stream_test".into();
        let mut f: Box<ExecutorFactory> = Box::new(|i, _s| {
            Ok(Box::new(StreamTestExecutor::new(None, 0.1 * (i + 1) as f32))
                as Box<dyn Executor>)
        });
        run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
        let v = ctl.model.get("key_000").unwrap().as_f32().unwrap();
        let oracle = 1.0 + (0.1 + 0.2 + 0.3 + 0.4 + 0.5) / 5.0;
        assert!(
            v.iter().all(|&x| (x - oracle).abs() < 1e-5),
            "expected {oracle}, got {}",
            v[0]
        );
    }

    #[test]
    fn tree_shard_straggler_is_dropped_at_the_mid_tier() {
        // 9 clients, branching 3; the last leaf stalls ~800 ms per task
        // (and would shift the mean by +100 if folded) while the job's
        // straggler timeout is 250 ms. The timeout is threaded down to
        // the shard gathers, so only the stalled leaf's contribution is
        // lost: its shard forwards a reduced-weight partial, every
        // subtree reports, and the aggregate stays on the fast-leaf
        // oracle.
        let n = 9;
        let mut job = crate::config::JobConfig::named("sim_tree_straggler", "none");
        job.rounds = 1;
        job.branching = 3;
        job.round_timeout_s = Some(0.25);
        job.clients = (0..n)
            .map(|i| ClientSpec {
                name: format!("site-{:02}", i + 1),
                bandwidth_bps: 0,
                partition: i,
            })
            .collect();
        job.min_clients = 3;
        let initial = StreamTestExecutor::build_model(2, 200, 1.0);
        let mut ctl = FedAvg::new(initial, 1, 3);
        ctl.task_name = "stream_test".into();
        let mut f: Box<ExecutorFactory> = Box::new(|i, _s| {
            Ok(if i == n - 1 {
                let mut e = StreamTestExecutor::new(None, 100.0);
                e.work_ms = 400;
                Box::new(e) as Box<dyn Executor>
            } else {
                Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>
            })
        });
        run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
        // all 3 subtrees reported a partial
        assert_eq!(ctl.history[0].per_client.len(), 3);
        // weights: shards fold 3 + 3 + 2 fast leaves, all at value 1.5
        let v = ctl.model.get("key_000").unwrap().as_f32().unwrap();
        assert!(
            v.iter().all(|&x| (x - 1.5).abs() < 1e-5),
            "stalled leaf leaked into the aggregate: {}",
            v[0]
        );
        let folded: f64 = ctl.history[0].per_client.iter().map(|(.., w)| w).sum();
        assert!((folded - 8.0).abs() < 1e-9, "expected 8 leaves folded: {folded}");
    }

    #[test]
    fn throttled_client_still_completes() {
        let mut job = crate::config::JobConfig::named("sim_throttle", "none");
        job.rounds = 1;
        job.stream.chunk_bytes = 4096;
        // site-2 at 2 MB/s with a ~80 kB model: measurable but quick
        job.clients[1].bandwidth_bps = 2_000_000;
        let initial = StreamTestExecutor::build_model(2, 10_000, 0.0);
        let mut ctl = FedAvg::new(initial, 1, 2);
        ctl.task_name = "stream_test".into();
        let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
            Ok(Box::new(StreamTestExecutor::new(None, 1.0)) as Box<dyn Executor>)
        });
        run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
        let v = ctl.model.get("key_000").unwrap().as_f32().unwrap();
        assert!((v[0] - 1.0).abs() < 1e-6);
    }

    /// Controller that records the order (and spacing) in which client
    /// results complete the streaming gather.
    struct OrderProbe {
        model: crate::tensor::TensorDict,
        order: Vec<String>,
        arrivals: Vec<std::time::Instant>,
    }

    impl crate::coordinator::Controller for OrderProbe {
        fn name(&self) -> &'static str {
            "order_probe"
        }
        fn run(
            &mut self,
            comm: &mut crate::coordinator::Communicator,
            _ctx: &mut crate::coordinator::ServerCtx,
        ) -> anyhow::Result<()> {
            let targets: Vec<usize> = (0..comm.n_clients()).collect();
            let task = FlMessage::task("stream_test", 0, self.model.clone());
            let (order, arrivals) = comm.broadcast_and_reduce(
                &task,
                &targets,
                (Vec::new(), Vec::new()),
                |(mut order, mut arrivals): (Vec<String>, Vec<_>), r| {
                    order.push(r.client.clone());
                    arrivals.push(std::time::Instant::now());
                    Ok((order, arrivals))
                },
            )?;
            self.order = order;
            self.arrivals = arrivals;
            comm.shutdown();
            Ok(())
        }
    }

    #[test]
    fn fast_client_is_folded_before_slow_client_arrives() {
        // site-2's whole connection is throttled to 8 MB/s on a 4 MB
        // model (shared-link token bucket, 1 MB burst), so its round trip
        // takes ~0.75 s while site-1 finishes in milliseconds; the
        // streaming gather must hand site-1's result to the fold while
        // site-2 is still mid-transfer.
        let mut job = crate::config::JobConfig::named("sim_order", "none");
        job.rounds = 1;
        job.stream.chunk_bytes = 64 << 10;
        job.clients[1].bandwidth_bps = 8_000_000;
        let mut ctl = OrderProbe {
            model: StreamTestExecutor::build_model(4, 262_144, 1.0),
            order: Vec::new(),
            arrivals: Vec::new(),
        };
        let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
            Ok(Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>)
        });
        run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
        assert_eq!(
            ctl.order,
            vec!["site-1".to_string(), "site-2".to_string()],
            "fast client must complete the gather first"
        );
        // the fold of the fast result happened well before the slow one
        // arrived (throttling stretches the gap to ~1 s; demand 200 ms)
        let gap = ctl.arrivals[1].duration_since(ctl.arrivals[0]);
        assert!(
            gap > std::time::Duration::from_millis(200),
            "no overlap between fold and slow transfer: gap {gap:?}"
        );
    }

    /// An executor that fails — the job must surface the error.
    struct Failing;
    impl Executor for Failing {
        fn execute(&mut self, _t: &FlMessage) -> Result<FlMessage> {
            Err(anyhow!("injected failure"))
        }
    }

    #[test]
    fn client_failure_propagates() {
        let mut job = crate::config::JobConfig::named("sim_fail", "none");
        job.rounds = 1;
        let initial = StreamTestExecutor::build_model(1, 10, 0.0);
        let mut ctl = FedAvg::new(initial, 1, 2);
        ctl.task_name = "stream_test".into();
        let mut f: Box<ExecutorFactory> =
            Box::new(|_i, _s| Ok(Box::new(Failing) as Box<dyn Executor>));
        let err = run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir());
        assert!(err.is_err());
    }

    #[test]
    fn filters_compose_with_fedavg() {
        // secure-agg masks must cancel in the FedAvg sum: same result as
        // without the filter
        let base = {
            let mut job = crate::config::JobConfig::named("sim_nofilter", "none");
            job.rounds = 2;
            let mut ctl = FedAvg::new(StreamTestExecutor::build_model(2, 50, 1.0), 2, 2);
            ctl.task_name = "stream_test".into();
            let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
                Ok(Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>)
            });
            run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
            ctl.model
        };
        let masked = {
            let mut job = crate::config::JobConfig::named("sim_secureagg", "none");
            job.rounds = 2;
            job.filters = vec![crate::config::FilterSpec::SecureAgg { seed: 5 }];
            let mut ctl = FedAvg::new(StreamTestExecutor::build_model(2, 50, 1.0), 2, 2);
            ctl.task_name = "stream_test".into();
            let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
                Ok(Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>)
            });
            run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
            ctl.model
        };
        // equal-weight (n_samples=1 each) FedAvg: masks cancel
        assert!(base.max_abs_diff(&masked) < 1e-4, "{}", base.max_abs_diff(&masked));
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join("fedflare_sim_tests"));
    }
}
