//! Multi-client simulation harness: runs a full FL job — server controller
//! plus N client task loops — in one process, over either the in-process
//! channel driver or real TCP loopback connections, with optional
//! per-client bandwidth throttling (the paper's fast/slow-site asymmetry).
//!
//! With `job.branching = B > 1` (and more than B clients) the harness
//! builds a **2-level aggregator tree** instead of the flat star: ⌈N/B⌉
//! mid-tier [`MidTier`] nodes each serve a contiguous shard of ≤ B
//! clients and forward one serialized partial per round, so the root's
//! fan-in is ⌈N/B⌉ partial streams rather than N client streams — same
//! wire format, same streaming folds, every link over the same driver.
//!
//! This is the engine behind `fedflare repro *`, the examples, and the
//! integration tests. Multi-process deployment (`fedflare server` /
//! `fedflare client`) shares all the same code paths; only connection
//! setup differs (see `main.rs`).

use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::config::{ClientSpec, FilterSpec, JobConfig};
use crate::coordinator::{
    accept_registration, shard_plan, ClientHandle, Communicator, Controller, GatherPolicy,
    MidTier, ServerCtx,
};
use crate::executor::{ClientRuntime, Executor};
use crate::filters::build_chain;
use crate::metrics::MetricsSink;
use crate::sfm::{inproc, tcp, throttle::Throttled, Driver};
use crate::streaming::Messenger;

/// Which transport the simulation uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DriverKind {
    /// Bounded in-process channels.
    InProc,
    /// Real TCP connections over loopback.
    Tcp,
}

/// Build the per-client executor (index, spec) -> Executor.
pub type ExecutorFactory<'a> = dyn FnMut(usize, &ClientSpec) -> Result<Box<dyn Executor>> + 'a;

/// What a finished job reports back beyond the controller's own fields.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    /// Peak decoded in-flight gather bytes at the **root** communicator
    /// (per-node counter — mid-tier folds are excluded, unlike the
    /// process-global [`crate::util::mem::gather_peak`]).
    pub root_gather_peak: u64,
}

/// Run a job to completion inside this process. The controller's own
/// fields (history, best model, ...) carry the results.
pub fn run_job(
    job: &JobConfig,
    kind: DriverKind,
    controller: &mut dyn Controller,
    make_executor: &mut ExecutorFactory,
    results_dir: &str,
) -> Result<RunReport> {
    if job.branching > 1 && job.clients.len() > job.branching {
        run_job_tree(job, kind, controller, make_executor, results_dir)
    } else {
        run_job_flat(job, kind, controller, make_executor, results_dir)
    }
}

fn run_job_flat(
    job: &JobConfig,
    kind: DriverKind,
    controller: &mut dyn Controller,
    make_executor: &mut ExecutorFactory,
    results_dir: &str,
) -> Result<RunReport> {
    let sink = MetricsSink::create(results_dir, &job.name)?;
    let mut ctx = ServerCtx::new(sink, &job.name);
    let chunk = job.stream.chunk_bytes;
    let window = job.stream.window;
    let verify = job.stream.verify_crc;

    // --- build transport pairs + client runtimes
    let mut client_threads = Vec::new();
    let mut server_messengers: Vec<Messenger> = Vec::new();

    match kind {
        DriverKind::InProc => {
            for (i, spec) in job.clients.iter().enumerate() {
                let (sa, ca) = inproc::pair(window, &spec.name);
                let client_driver: Box<dyn Driver> = wrap_throttle(Box::new(ca), spec);
                let server_driver: Box<dyn Driver> = wrap_throttle(Box::new(sa), spec);
                server_messengers.push(Messenger::new(server_driver, chunk, 0));
                let messenger = Messenger::new(client_driver, chunk, (i + 1) as u32);
                client_threads.push(spawn_client(job, i, spec, messenger, make_executor)?);
            }
        }
        DriverKind::Tcp => {
            let listener = tcp::bind("127.0.0.1:0")?;
            let addr = listener.local_addr().context("local addr")?;
            for (i, spec) in job.clients.iter().enumerate() {
                let drv = tcp::TcpDriver::connect(addr, verify)?;
                let client_driver: Box<dyn Driver> = wrap_throttle(Box::new(drv), spec);
                let messenger = Messenger::new(client_driver, chunk, (i + 1) as u32);
                client_threads.push(spawn_client(job, i, spec, messenger, make_executor)?);
                let (conn, _) = listener.accept().context("accept")?;
                let sdrv = tcp::TcpDriver::from_stream(conn, verify)?;
                // server->client direction throttled too (a slow link is
                // slow both ways)
                let server_driver: Box<dyn Driver> = wrap_throttle(Box::new(sdrv), spec);
                server_messengers.push(Messenger::new(server_driver, chunk, 0));
            }
        }
    }

    // --- registration handshake, then per-client IO workers
    let mut handles = Vec::new();
    for mut m in server_messengers {
        let name = accept_registration(&mut m)?;
        handles.push(ClientHandle::spawn(name, m));
    }
    // order handles to match job.clients order (TCP accepts may race)
    handles.sort_by_key(|h| {
        job.clients
            .iter()
            .position(|c| c.name == h.name)
            .unwrap_or(usize::MAX)
    });
    let mut comm = Communicator::new(handles, job.seed);
    let counter = comm.gather_counter();

    // --- run the workflow
    let run_result = controller.run(&mut comm, &mut ctx);

    // tear the transport down even when the controller failed mid-round,
    // so idle clients observe a bye (or a closed channel) instead of
    // blocking on their next task while we join them below
    if run_result.is_err() {
        comm.shutdown();
    }
    drop(comm);

    // --- join clients
    let mut client_errs = Vec::new();
    for (name, t) in client_threads {
        match t.join() {
            Ok(Ok(_tasks)) => {}
            Ok(Err(e)) => client_errs.push(format!("{name}: {e}")),
            Err(_) => client_errs.push(format!("{name}: panicked")),
        }
    }
    run_result?;
    if !client_errs.is_empty() {
        return Err(anyhow!("client failures: {}", client_errs.join("; ")));
    }
    Ok(RunReport {
        root_gather_peak: counter.peak(),
    })
}

/// The 2-level aggregator tree (see module docs): spawn every leaf
/// client, one mid-tier node per shard, and run the controller against
/// the mid-tier nodes only.
fn run_job_tree(
    job: &JobConfig,
    kind: DriverKind,
    controller: &mut dyn Controller,
    make_executor: &mut ExecutorFactory,
    results_dir: &str,
) -> Result<RunReport> {
    let sink = MetricsSink::create(results_dir, &job.name)?;
    let mut ctx = ServerCtx::new(sink, &job.name);
    let chunk = job.stream.chunk_bytes;
    let window = job.stream.window;
    let verify = job.stream.verify_crc;
    let shards = shard_plan(job.clients.len(), job.branching);
    // the trailing-codec receive mirror runs where client streams land:
    // on the mid-tier nodes (partials forwarded upstream are plain f32)
    let mid_recv_filters = FilterSpec::receive_chain(&job.filters);
    // thread the straggler timeout down to the shard gathers: a stalled
    // leaf costs only its own contribution (quorum 1 — the shard forwards
    // a reduced-weight partial) instead of wedging its whole subtree
    let mid_policy = match job.round_timeout_s {
        None => GatherPolicy::all(),
        Some(t) => GatherPolicy {
            quorum: 1,
            timeout: Some(std::time::Duration::from_secs_f64(t)),
        },
    };

    let mut client_threads = Vec::new();
    let mut mid_threads = Vec::new();
    let mut root_messengers: Vec<Messenger> = Vec::new();

    match kind {
        DriverKind::InProc => {
            for (m, shard) in shards.iter().enumerate() {
                let mid_name = format!("agg-{m:03}");
                let (ra, ma) = inproc::pair(window, &mid_name);
                root_messengers.push(Messenger::new(Box::new(ra), chunk, 0));
                let upstream =
                    Messenger::new(Box::new(ma), chunk, (job.clients.len() + m + 1) as u32);
                let mut shard_msgrs = Vec::new();
                let mut shard_names = Vec::new();
                for i in shard.clone() {
                    let spec = &job.clients[i];
                    let (sa, ca) = inproc::pair(window, &spec.name);
                    shard_msgrs.push(Messenger::new(wrap_throttle(Box::new(sa), spec), chunk, 0));
                    let cm =
                        Messenger::new(wrap_throttle(Box::new(ca), spec), chunk, (i + 1) as u32);
                    client_threads.push(spawn_client(job, i, spec, cm, make_executor)?);
                    shard_names.push(spec.name.clone());
                }
                mid_threads.push(spawn_midtier(
                    mid_name,
                    upstream,
                    shard_msgrs,
                    shard_names,
                    mid_recv_filters.clone(),
                    mid_policy.clone(),
                    job.seed ^ (m as u64 + 1),
                )?);
            }
        }
        DriverKind::Tcp => {
            let root_listener = tcp::bind("127.0.0.1:0")?;
            let root_addr = root_listener.local_addr().context("root addr")?;
            for (m, shard) in shards.iter().enumerate() {
                let mid_name = format!("agg-{m:03}");
                let up_drv = tcp::TcpDriver::connect(root_addr, verify)?;
                let (conn, _) = root_listener.accept().context("accept midtier")?;
                root_messengers.push(Messenger::new(
                    Box::new(tcp::TcpDriver::from_stream(conn, verify)?),
                    chunk,
                    0,
                ));
                let upstream = Messenger::new(
                    Box::new(up_drv),
                    chunk,
                    (job.clients.len() + m + 1) as u32,
                );
                let mid_listener = tcp::bind("127.0.0.1:0")?;
                let mid_addr = mid_listener.local_addr().context("midtier addr")?;
                let mut shard_msgrs = Vec::new();
                let mut shard_names = Vec::new();
                for i in shard.clone() {
                    let spec = &job.clients[i];
                    let drv = tcp::TcpDriver::connect(mid_addr, verify)?;
                    let cm =
                        Messenger::new(wrap_throttle(Box::new(drv), spec), chunk, (i + 1) as u32);
                    client_threads.push(spawn_client(job, i, spec, cm, make_executor)?);
                    let (conn, _) = mid_listener.accept().context("accept leaf")?;
                    shard_msgrs.push(Messenger::new(
                        wrap_throttle(Box::new(tcp::TcpDriver::from_stream(conn, verify)?), spec),
                        chunk,
                        0,
                    ));
                    shard_names.push(spec.name.clone());
                }
                mid_threads.push(spawn_midtier(
                    mid_name,
                    upstream,
                    shard_msgrs,
                    shard_names,
                    mid_recv_filters.clone(),
                    mid_policy.clone(),
                    job.seed ^ (m as u64 + 1),
                )?);
            }
        }
    }

    // --- root registration: mid-tier nodes register over their upstream
    let mut handles = Vec::new();
    for mut m in root_messengers {
        let name = accept_registration(&mut m)?;
        handles.push(ClientHandle::spawn(name, m));
    }
    // zero-padded names sort to shard order
    handles.sort_by(|a, b| a.name.cmp(&b.name));
    let mut comm = Communicator::new(handles, job.seed);
    let counter = comm.gather_counter();

    let run_result = controller.run(&mut comm, &mut ctx);
    if run_result.is_err() {
        comm.shutdown();
    }
    drop(comm);

    // --- join mid-tier nodes, then clients
    let mut errs = Vec::new();
    for (name, t) in mid_threads {
        match t.join() {
            Ok(Ok(_rounds)) => {}
            Ok(Err(e)) => errs.push(format!("{name}: {e}")),
            Err(_) => errs.push(format!("{name}: panicked")),
        }
    }
    for (name, t) in client_threads {
        match t.join() {
            Ok(Ok(_tasks)) => {}
            Ok(Err(e)) => errs.push(format!("{name}: {e}")),
            Err(_) => errs.push(format!("{name}: panicked")),
        }
    }
    run_result?;
    if !errs.is_empty() {
        return Err(anyhow!("node failures: {}", errs.join("; ")));
    }
    Ok(RunReport {
        root_gather_peak: counter.peak(),
    })
}

fn wrap_throttle(driver: Box<dyn Driver>, spec: &ClientSpec) -> Box<dyn Driver> {
    if spec.bandwidth_bps > 0 {
        Box::new(Throttled::new(
            BoxedDriver(driver),
            spec.bandwidth_bps,
            crate::DEFAULT_CHUNK_BYTES as u64,
        ))
    } else {
        driver
    }
}

/// Adapter: `Box<dyn Driver>` itself as a Driver (for the Throttled
/// decorator, which is generic).
struct BoxedDriver(Box<dyn Driver>);

impl Driver for BoxedDriver {
    fn send(&mut self, frame: crate::sfm::Frame) -> Result<(), crate::sfm::SfmError> {
        self.0.send(frame)
    }
    fn recv(&mut self) -> Result<crate::sfm::Frame, crate::sfm::SfmError> {
        self.0.recv()
    }
    fn name(&self) -> String {
        self.0.name()
    }
}

type ClientThread = (String, std::thread::JoinHandle<Result<usize>>);

fn spawn_client(
    job: &JobConfig,
    idx: usize,
    spec: &ClientSpec,
    messenger: Messenger,
    make_executor: &mut ExecutorFactory,
) -> Result<ClientThread> {
    let executor = make_executor(idx, spec)?;
    let filters = build_chain(&job.filters, idx, job.clients.len());
    let name = spec.name.clone();
    let tname = name.clone();
    let handle = std::thread::Builder::new()
        .name(format!("client-{name}"))
        .spawn(move || {
            let mut rt = ClientRuntime::new(&tname, messenger, executor, filters);
            rt.run_loop()
        })
        .context("spawn client thread")?;
    Ok((name, handle))
}

/// Spawn one mid-tier aggregator node: accept its shard's registrations,
/// build its communicator, and serve rounds until the upstream bye.
fn spawn_midtier(
    name: String,
    upstream: Messenger,
    shard_messengers: Vec<Messenger>,
    shard_names: Vec<String>,
    recv_filters: Vec<FilterSpec>,
    policy: GatherPolicy,
    seed: u64,
) -> Result<(String, std::thread::JoinHandle<Result<usize>>)> {
    let tname = name.clone();
    let shard_names = Arc::new(shard_names);
    let handle = std::thread::Builder::new()
        .name(format!("midtier-{name}"))
        .spawn(move || -> Result<usize> {
            let mut handles = Vec::new();
            for mut m in shard_messengers {
                let n = accept_registration(&mut m)?;
                handles.push(ClientHandle::spawn(n, m));
            }
            // order handles to the shard's job order (TCP accepts may race)
            handles.sort_by_key(|h| {
                shard_names
                    .iter()
                    .position(|c| *c == h.name)
                    .unwrap_or(usize::MAX)
            });
            let comm = Communicator::new(handles, seed);
            MidTier::new(&tname, upstream, comm, recv_filters, policy).run()
        })
        .context("spawn midtier thread")?;
    Ok((name, handle))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::FedAvg;
    use crate::executor::StreamTestExecutor;
    use crate::message::FlMessage;
    use crate::util::json::Json;

    fn results_dir() -> String {
        let d = std::env::temp_dir().join("fedflare_sim_tests");
        let _ = std::fs::create_dir_all(&d);
        d.to_string_lossy().to_string()
    }

    /// FedAvg over the add-delta workload: after R rounds with all clients
    /// adding d, the global model is exactly initial + R*d (weights sum
    /// to 1 each round).
    fn add_delta_fedavg(kind: DriverKind, chunk: usize) {
        let mut job = crate::config::JobConfig::named("sim_add", "none");
        job.rounds = 3;
        job.min_clients = 2;
        job.stream.chunk_bytes = chunk;
        let initial = StreamTestExecutor::build_model(4, 1000, 1.0);
        let mut ctl = FedAvg::new(initial, job.rounds, job.min_clients);
        ctl.task_name = "stream_test".into();
        let mut factory: Box<ExecutorFactory> = Box::new(|_i, _s| {
            Ok(Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>)
        });
        run_job(&job, kind, &mut ctl, &mut factory, &results_dir()).unwrap();
        let v = ctl.model.get("key_000").unwrap().as_f32().unwrap();
        assert!(
            v.iter().all(|&x| (x - 2.5).abs() < 1e-5),
            "expected 1.0 + 3*0.5, got {}",
            v[0]
        );
        assert_eq!(ctl.history.len(), 3);
    }

    #[test]
    fn fedavg_add_delta_inproc() {
        add_delta_fedavg(DriverKind::InProc, 1024);
    }

    #[test]
    fn fedavg_add_delta_tcp() {
        add_delta_fedavg(DriverKind::Tcp, 1024);
    }

    #[test]
    fn driver_swap_changes_nothing_above_sfm() {
        // the paper's SFM claim: same job, same numbers, different driver
        let run = |kind| {
            let mut job = crate::config::JobConfig::named("sim_swap", "none");
            job.rounds = 2;
            let initial = StreamTestExecutor::build_model(2, 100, 0.0);
            let mut ctl = FedAvg::new(initial, 2, 2);
            ctl.task_name = "stream_test".into();
            let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
                Ok(Box::new(StreamTestExecutor::new(None, 0.25)) as Box<dyn Executor>)
            });
            run_job(&job, kind, &mut ctl, &mut f, &results_dir()).unwrap();
            ctl.model
        };
        let a = run(DriverKind::InProc);
        let b = run(DriverKind::Tcp);
        assert_eq!(a, b);
    }

    /// A hierarchical job over `kind`: n clients, branching b, every
    /// client adding delta — the tree must converge to the flat oracle.
    fn add_delta_tree(kind: DriverKind, n: usize, b: usize) {
        let mut job = crate::config::JobConfig::named(&format!("sim_tree_{n}_{b}"), "none");
        job.rounds = 2;
        job.branching = b;
        job.clients = (0..n)
            .map(|i| ClientSpec {
                name: format!("site-{:02}", i + 1),
                bandwidth_bps: 0,
                partition: i,
            })
            .collect();
        let n_mid = n.div_ceil(b);
        job.min_clients = n_mid;
        let initial = StreamTestExecutor::build_model(3, 500, 1.0);
        let mut ctl = FedAvg::new(initial, job.rounds, n_mid);
        ctl.task_name = "stream_test".into();
        let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
            Ok(Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>)
        });
        run_job(&job, kind, &mut ctl, &mut f, &results_dir()).unwrap();
        let v = ctl.model.get("key_000").unwrap().as_f32().unwrap();
        assert!(
            v.iter().all(|&x| (x - 2.0).abs() < 1e-5),
            "expected 1.0 + 2*0.5, got {}",
            v[0]
        );
        assert_eq!(ctl.history.len(), 2);
        // the root gathered partials from every mid-tier node
        assert_eq!(ctl.history[0].per_client.len(), n_mid);
        assert!(ctl.history[0].per_client[0].0.starts_with("agg-"));
    }

    #[test]
    fn hierarchical_tree_matches_flat_oracle_inproc() {
        add_delta_tree(DriverKind::InProc, 9, 3);
    }

    #[test]
    fn hierarchical_tree_matches_flat_oracle_tcp() {
        add_delta_tree(DriverKind::Tcp, 8, 3);
    }

    #[test]
    fn tree_with_uneven_shards_weights_partials_correctly() {
        // 5 clients, branching 2 -> shards of 2/2/1. Client i adds
        // delta_i = 0.1*(i+1) with weight 1 each; the global mean is the
        // plain average of deltas — partial weighting must reproduce it.
        let n = 5;
        let mut job = crate::config::JobConfig::named("sim_tree_uneven", "none");
        job.rounds = 1;
        job.branching = 2;
        job.clients = (0..n)
            .map(|i| ClientSpec {
                name: format!("site-{:02}", i + 1),
                bandwidth_bps: 0,
                partition: i,
            })
            .collect();
        job.min_clients = 3;
        let initial = StreamTestExecutor::build_model(2, 200, 1.0);
        let mut ctl = FedAvg::new(initial, 1, 3);
        ctl.task_name = "stream_test".into();
        let mut f: Box<ExecutorFactory> = Box::new(|i, _s| {
            Ok(Box::new(StreamTestExecutor::new(None, 0.1 * (i + 1) as f32))
                as Box<dyn Executor>)
        });
        run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
        let v = ctl.model.get("key_000").unwrap().as_f32().unwrap();
        let oracle = 1.0 + (0.1 + 0.2 + 0.3 + 0.4 + 0.5) / 5.0;
        assert!(
            v.iter().all(|&x| (x - oracle).abs() < 1e-5),
            "expected {oracle}, got {}",
            v[0]
        );
    }

    #[test]
    fn tree_shard_straggler_is_dropped_at_the_mid_tier() {
        // 9 clients, branching 3; the last leaf stalls ~800 ms per task
        // (and would shift the mean by +100 if folded) while the job's
        // straggler timeout is 250 ms. The timeout is threaded down to
        // the shard gathers, so only the stalled leaf's contribution is
        // lost: its shard forwards a reduced-weight partial, every
        // subtree reports, and the aggregate stays on the fast-leaf
        // oracle.
        let n = 9;
        let mut job = crate::config::JobConfig::named("sim_tree_straggler", "none");
        job.rounds = 1;
        job.branching = 3;
        job.round_timeout_s = Some(0.25);
        job.clients = (0..n)
            .map(|i| ClientSpec {
                name: format!("site-{:02}", i + 1),
                bandwidth_bps: 0,
                partition: i,
            })
            .collect();
        job.min_clients = 3;
        let initial = StreamTestExecutor::build_model(2, 200, 1.0);
        let mut ctl = FedAvg::new(initial, 1, 3);
        ctl.task_name = "stream_test".into();
        let mut f: Box<ExecutorFactory> = Box::new(|i, _s| {
            Ok(if i == n - 1 {
                let mut e = StreamTestExecutor::new(None, 100.0);
                e.work_ms = 400;
                Box::new(e) as Box<dyn Executor>
            } else {
                Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>
            })
        });
        run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
        // all 3 subtrees reported a partial
        assert_eq!(ctl.history[0].per_client.len(), 3);
        // weights: shards fold 3 + 3 + 2 fast leaves, all at value 1.5
        let v = ctl.model.get("key_000").unwrap().as_f32().unwrap();
        assert!(
            v.iter().all(|&x| (x - 1.5).abs() < 1e-5),
            "stalled leaf leaked into the aggregate: {}",
            v[0]
        );
        let folded: f64 = ctl.history[0].per_client.iter().map(|(.., w)| w).sum();
        assert!((folded - 8.0).abs() < 1e-9, "expected 8 leaves folded: {folded}");
    }

    #[test]
    fn throttled_client_still_completes() {
        let mut job = crate::config::JobConfig::named("sim_throttle", "none");
        job.rounds = 1;
        job.stream.chunk_bytes = 4096;
        // site-2 at 2 MB/s with a ~80 kB model: measurable but quick
        job.clients[1].bandwidth_bps = 2_000_000;
        let initial = StreamTestExecutor::build_model(2, 10_000, 0.0);
        let mut ctl = FedAvg::new(initial, 1, 2);
        ctl.task_name = "stream_test".into();
        let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
            Ok(Box::new(StreamTestExecutor::new(None, 1.0)) as Box<dyn Executor>)
        });
        run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
        let v = ctl.model.get("key_000").unwrap().as_f32().unwrap();
        assert!((v[0] - 1.0).abs() < 1e-6);
    }

    /// Controller that records the order (and spacing) in which client
    /// results complete the streaming gather.
    struct OrderProbe {
        model: crate::tensor::TensorDict,
        order: Vec<String>,
        arrivals: Vec<std::time::Instant>,
    }

    impl crate::coordinator::Controller for OrderProbe {
        fn name(&self) -> &'static str {
            "order_probe"
        }
        fn run(
            &mut self,
            comm: &mut crate::coordinator::Communicator,
            _ctx: &mut crate::coordinator::ServerCtx,
        ) -> anyhow::Result<()> {
            let targets: Vec<usize> = (0..comm.n_clients()).collect();
            let task = FlMessage::task("stream_test", 0, self.model.clone());
            let (order, arrivals) = comm.broadcast_and_reduce(
                &task,
                &targets,
                (Vec::new(), Vec::new()),
                |(mut order, mut arrivals): (Vec<String>, Vec<_>), r| {
                    order.push(r.client.clone());
                    arrivals.push(std::time::Instant::now());
                    Ok((order, arrivals))
                },
            )?;
            self.order = order;
            self.arrivals = arrivals;
            comm.shutdown();
            Ok(())
        }
    }

    #[test]
    fn fast_client_is_folded_before_slow_client_arrives() {
        // site-2 is throttled to 8 MB/s on a 4 MB model (both directions;
        // the token bucket's 1 MB burst covers only the first chunk-span),
        // so its round trip takes ~0.75 s while site-1 finishes in
        // milliseconds; the streaming gather must hand site-1's result to
        // the fold while site-2 is still mid-transfer.
        let mut job = crate::config::JobConfig::named("sim_order", "none");
        job.rounds = 1;
        job.stream.chunk_bytes = 64 << 10;
        job.clients[1].bandwidth_bps = 8_000_000;
        let mut ctl = OrderProbe {
            model: StreamTestExecutor::build_model(4, 262_144, 1.0),
            order: Vec::new(),
            arrivals: Vec::new(),
        };
        let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
            Ok(Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>)
        });
        run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
        assert_eq!(
            ctl.order,
            vec!["site-1".to_string(), "site-2".to_string()],
            "fast client must complete the gather first"
        );
        // the fold of the fast result happened well before the slow one
        // arrived (throttling stretches the gap to ~1 s; demand 200 ms)
        let gap = ctl.arrivals[1].duration_since(ctl.arrivals[0]);
        assert!(
            gap > std::time::Duration::from_millis(200),
            "no overlap between fold and slow transfer: gap {gap:?}"
        );
    }

    /// An executor that fails — the job must surface the error.
    struct Failing;
    impl Executor for Failing {
        fn execute(&mut self, _t: &FlMessage) -> Result<FlMessage> {
            Err(anyhow!("injected failure"))
        }
    }

    #[test]
    fn client_failure_propagates() {
        let mut job = crate::config::JobConfig::named("sim_fail", "none");
        job.rounds = 1;
        let initial = StreamTestExecutor::build_model(1, 10, 0.0);
        let mut ctl = FedAvg::new(initial, 1, 2);
        ctl.task_name = "stream_test".into();
        let mut f: Box<ExecutorFactory> =
            Box::new(|_i, _s| Ok(Box::new(Failing) as Box<dyn Executor>));
        let err = run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir());
        assert!(err.is_err());
    }

    #[test]
    fn filters_compose_with_fedavg() {
        // secure-agg masks must cancel in the FedAvg sum: same result as
        // without the filter
        let base = {
            let mut job = crate::config::JobConfig::named("sim_nofilter", "none");
            job.rounds = 2;
            let mut ctl = FedAvg::new(StreamTestExecutor::build_model(2, 50, 1.0), 2, 2);
            ctl.task_name = "stream_test".into();
            let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
                Ok(Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>)
            });
            run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
            ctl.model
        };
        let masked = {
            let mut job = crate::config::JobConfig::named("sim_secureagg", "none");
            job.rounds = 2;
            job.filters = vec![crate::config::FilterSpec::SecureAgg { seed: 5 }];
            let mut ctl = FedAvg::new(StreamTestExecutor::build_model(2, 50, 1.0), 2, 2);
            ctl.task_name = "stream_test".into();
            let mut f: Box<ExecutorFactory> = Box::new(|_i, _s| {
                Ok(Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>)
            });
            run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
            ctl.model
        };
        // equal-weight (n_samples=1 each) FedAvg: masks cancel
        assert!(base.max_abs_diff(&masked) < 1e-4, "{}", base.max_abs_diff(&masked));
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join("fedflare_sim_tests"));
    }
}
