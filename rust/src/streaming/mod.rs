//! Object / blob / file streaming over the SFM frame layer (the paper's
//! four "streaming API variations": byte, blob, file, object).
//!
//! [`Messenger`] is what the coordinator/executor layers actually hold: it
//! owns a [`Driver`], allocates stream ids, chunks outgoing payloads
//! (1 MB default), reassembles incoming ones, and converts to/from
//! [`FlMessage`]. Send/receive of a 128 MB model and of a 40-byte control
//! message go through the identical code path — only the chunk count
//! differs.

use std::io::{Read, Write};
use std::path::Path;

use crate::message::{FlMessage, MessageError};
use crate::sfm::{chunk_frames, Driver, Frame, Reassembler, SfmError, FLAG_FIRST, FLAG_LAST};
use crate::util::mem;

/// Application payload tags carried in the SFM `kind` field.
pub const KIND_BYTES: u16 = 0;
pub const KIND_BLOB: u16 = 1;
pub const KIND_OBJECT: u16 = 2;
pub const KIND_FILE: u16 = 3;

/// Streaming-layer errors.
#[derive(Debug, thiserror::Error)]
pub enum StreamError {
    #[error(transparent)]
    Sfm(#[from] SfmError),
    #[error("message: {0}")]
    Message(#[from] MessageError),
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("protocol: {0}")]
    Protocol(String),
}

/// A received payload, tagged with its stream kind.
#[derive(Debug)]
pub enum Received {
    Bytes(Vec<u8>),
    Blob(Vec<u8>),
    Object(FlMessage),
    /// File content held as bytes (use [`Messenger::recv_file`] to spool
    /// to disk instead).
    File(Vec<u8>),
}

/// Duplex streaming endpoint over any SFM driver.
pub struct Messenger {
    driver: Box<dyn Driver>,
    reasm: Reassembler,
    chunk_bytes: usize,
    next_stream: u64,
    /// Running transfer counters (bytes of payload, not counting headers).
    pub sent_bytes: u64,
    pub recv_bytes: u64,
}

impl Messenger {
    /// `tag` disambiguates stream ids between endpoints (e.g. client idx).
    pub fn new(driver: Box<dyn Driver>, chunk_bytes: usize, tag: u32) -> Messenger {
        Messenger {
            driver,
            reasm: Reassembler::new(),
            chunk_bytes,
            next_stream: (tag as u64) << 32,
            sent_bytes: 0,
            recv_bytes: 0,
        }
    }

    pub fn driver_name(&self) -> String {
        self.driver.name()
    }

    fn alloc_stream(&mut self) -> u64 {
        self.next_stream += 1;
        self.next_stream
    }

    /// Stream raw bytes (`kind` selects byte/blob semantics upstream).
    fn send_tagged(&mut self, kind: u16, payload: &[u8]) -> Result<(), StreamError> {
        let stream = self.alloc_stream();
        // Stage-and-send: the outgoing message is materialized once (this
        // is the "model + runtime space" the paper's Fig-5 memory math
        // counts on the sender side), then chunked out.
        mem::track_alloc(payload.len());
        let result = (|| {
            for frame in chunk_frames(kind, stream, payload, self.chunk_bytes) {
                self.sent_bytes += frame.payload.len() as u64;
                self.driver.send(frame)?;
            }
            Ok(())
        })();
        mem::track_free(payload.len());
        result
    }

    /// Paper variation 1: raw byte streaming.
    pub fn send_bytes(&mut self, payload: &[u8]) -> Result<(), StreamError> {
        self.send_tagged(KIND_BYTES, payload)
    }

    /// Paper variation 2: blob streaming (semantically one opaque value).
    pub fn send_blob(&mut self, payload: &[u8]) -> Result<(), StreamError> {
        self.send_tagged(KIND_BLOB, payload)
    }

    /// Paper variation 4: object streaming — the FL workhorse.
    pub fn send_msg(&mut self, msg: &FlMessage) -> Result<(), StreamError> {
        let bytes = msg.to_bytes();
        self.send_tagged(KIND_OBJECT, &bytes)
    }

    /// Paper variation 3: file streaming. Reads and sends chunk-by-chunk,
    /// never holding the whole file in memory.
    pub fn send_file(&mut self, path: &Path) -> Result<(), StreamError> {
        let meta = std::fs::metadata(path)?;
        let size = meta.len() as usize;
        let stream = self.alloc_stream();
        let total = size.div_ceil(self.chunk_bytes).max(1) as u32;
        let mut file = std::fs::File::open(path)?;
        let mut buf = vec![0u8; self.chunk_bytes];
        for seq in 0..total {
            let want = if seq == total - 1 && size > 0 {
                size - seq as usize * self.chunk_bytes
            } else if size == 0 {
                0
            } else {
                self.chunk_bytes
            };
            file.read_exact(&mut buf[..want])?;
            let mut flags = 0;
            if seq == 0 {
                flags |= FLAG_FIRST;
            }
            if seq == total - 1 {
                flags |= FLAG_LAST;
            }
            self.sent_bytes += want as u64;
            self.driver.send(Frame {
                flags,
                kind: KIND_FILE,
                stream,
                seq,
                total,
                payload: buf[..want].to_vec(),
            })?;
        }
        Ok(())
    }

    /// Block until the next complete payload arrives (any kind).
    pub fn recv(&mut self) -> Result<Received, StreamError> {
        loop {
            let frame = self.driver.recv()?;
            self.recv_bytes += frame.payload.len() as u64;
            if let Some((_stream, kind, payload)) = self.reasm.push(frame)? {
                // ownership transferred to the caller; release tracking here
                mem::track_free(payload.len());
                return Ok(match kind {
                    KIND_BYTES => Received::Bytes(payload),
                    KIND_BLOB => Received::Blob(payload),
                    KIND_OBJECT => Received::Object(FlMessage::from_bytes(&payload)?),
                    KIND_FILE => Received::File(payload),
                    other => {
                        return Err(StreamError::Protocol(format!(
                            "unknown stream kind {other}"
                        )))
                    }
                });
            }
        }
    }

    /// Block until the next [`FlMessage`] arrives (errors on other kinds —
    /// the FL protocol only exchanges objects).
    pub fn recv_msg(&mut self) -> Result<FlMessage, StreamError> {
        match self.recv()? {
            Received::Object(m) => Ok(m),
            other => Err(StreamError::Protocol(format!(
                "expected object stream, got {}",
                match other {
                    Received::Bytes(_) => "bytes",
                    Received::Blob(_) => "blob",
                    Received::File(_) => "file",
                    Received::Object(_) => unreachable!(),
                }
            ))),
        }
    }

    /// Receive a file stream directly to disk, writing chunks as the
    /// contiguous prefix grows (out-of-order chunks are buffered).
    ///
    /// The first frame latches the stream id and chunk count; frames from
    /// any other stream — or frames whose `total` disagrees — are a
    /// protocol error rather than silent corruption of the output file.
    pub fn recv_file(&mut self, out: &Path) -> Result<u64, StreamError> {
        let mut file = std::fs::File::create(out)?;
        let mut pending: std::collections::BTreeMap<u32, Vec<u8>> = Default::default();
        let mut latched: Option<(u64, u32)> = None; // (stream id, total)
        let mut next_seq = 0u32;
        let mut written = 0u64;
        loop {
            let frame = self.driver.recv()?;
            if frame.kind != KIND_FILE {
                return Err(StreamError::Protocol(
                    "interleaved non-file stream during recv_file".into(),
                ));
            }
            let (stream, total) = match latched {
                None => {
                    if frame.total == 0 {
                        return Err(StreamError::Protocol(
                            "file stream with total=0".into(),
                        ));
                    }
                    latched = Some((frame.stream, frame.total));
                    (frame.stream, frame.total)
                }
                Some(l) => l,
            };
            if frame.stream != stream {
                return Err(StreamError::Protocol(format!(
                    "interleaved file stream {} during recv_file of stream {stream}",
                    frame.stream
                )));
            }
            if frame.total != total {
                return Err(StreamError::Protocol(format!(
                    "file stream {stream}: inconsistent total ({} vs {total})",
                    frame.total
                )));
            }
            if frame.seq >= total {
                return Err(StreamError::Protocol(format!(
                    "file stream {stream}: seq {} >= total {total}",
                    frame.seq
                )));
            }
            self.recv_bytes += frame.payload.len() as u64;
            pending.insert(frame.seq, frame.payload);
            while let Some(chunk) = pending.remove(&next_seq) {
                file.write_all(&chunk)?;
                written += chunk.len() as u64;
                next_seq += 1;
            }
            if next_seq == total {
                file.flush()?;
                return Ok(written);
            }
        }
    }

    /// Send the end-of-job control message.
    pub fn send_bye(&mut self) -> Result<(), StreamError> {
        self.send_msg(&FlMessage::bye())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sfm::inproc;
    use crate::tensor::{Tensor, TensorDict};

    fn pair(chunk: usize) -> (Messenger, Messenger) {
        let (a, b) = inproc::pair(64, "m");
        (
            Messenger::new(Box::new(a), chunk, 1),
            Messenger::new(Box::new(b), chunk, 2),
        )
    }

    #[test]
    fn object_roundtrip_multi_chunk() {
        let (mut a, mut b) = pair(256);
        let mut body = TensorDict::new();
        body.insert("w", Tensor::f32(vec![1000], vec![0.5; 1000])); // ~4 kB
        let msg = FlMessage::task("train", 2, body);
        a.send_msg(&msg).unwrap();
        let got = b.recv_msg().unwrap();
        assert_eq!(got, msg);
        assert!(a.sent_bytes >= 4000);
        assert_eq!(a.sent_bytes, b.recv_bytes);
    }

    #[test]
    fn bytes_blob_kinds_distinguished() {
        let (mut a, mut b) = pair(64);
        a.send_bytes(&[1, 2, 3]).unwrap();
        a.send_blob(&[4, 5]).unwrap();
        assert!(matches!(b.recv().unwrap(), Received::Bytes(v) if v == vec![1,2,3]));
        assert!(matches!(b.recv().unwrap(), Received::Blob(v) if v == vec![4,5]));
    }

    #[test]
    fn recv_msg_rejects_wrong_kind() {
        let (mut a, mut b) = pair(64);
        a.send_bytes(&[9]).unwrap();
        assert!(b.recv_msg().is_err());
    }

    #[test]
    fn empty_message_roundtrip() {
        let (mut a, mut b) = pair(1024);
        a.send_msg(&FlMessage::bye()).unwrap();
        let got = b.recv_msg().unwrap();
        assert_eq!(got.kind, crate::message::Kind::Bye);
    }

    #[test]
    fn file_streaming_roundtrip() {
        let dir = std::env::temp_dir();
        let src = dir.join("fedflare_test_src.bin");
        let dst = dir.join("fedflare_test_dst.bin");
        let data: Vec<u8> = (0..30_000u32).map(|i| (i % 253) as u8).collect();
        std::fs::write(&src, &data).unwrap();

        let (mut a, mut b) = pair(1024);
        let send = {
            let src = src.clone();
            std::thread::spawn(move || {
                a.send_file(&src).unwrap();
                a
            })
        };
        let written = b.recv_file(&dst).unwrap();
        send.join().unwrap();
        assert_eq!(written, data.len() as u64);
        assert_eq!(std::fs::read(&dst).unwrap(), data);
        let _ = std::fs::remove_file(&src);
        let _ = std::fs::remove_file(&dst);
    }

    #[test]
    fn recv_file_rejects_interleaved_second_stream() {
        use crate::sfm::{Driver, Frame};
        let (mut raw, b) = inproc::pair(64, "ifile");
        let mut b = Messenger::new(Box::new(b), 1024, 2);
        let mk = |stream: u64, seq: u32, total: u32| Frame {
            flags: 0,
            kind: KIND_FILE,
            stream,
            seq,
            total,
            payload: vec![seq as u8; 16],
        };
        raw.send(mk(1, 0, 3)).unwrap();
        raw.send(mk(2, 0, 3)).unwrap(); // second stream interleaves
        let dst = std::env::temp_dir().join("fedflare_recv_file_interleave.bin");
        let err = b.recv_file(&dst).unwrap_err();
        assert!(
            err.to_string().contains("interleaved file stream"),
            "{err}"
        );
        let _ = std::fs::remove_file(&dst);
    }

    #[test]
    fn recv_file_rejects_inconsistent_total() {
        use crate::sfm::{Driver, Frame};
        let (mut raw, b) = inproc::pair(64, "tfile");
        let mut b = Messenger::new(Box::new(b), 1024, 2);
        let mk = |seq: u32, total: u32| Frame {
            flags: 0,
            kind: KIND_FILE,
            stream: 9,
            seq,
            total,
            payload: vec![seq as u8; 16],
        };
        raw.send(mk(0, 3)).unwrap();
        raw.send(mk(1, 4)).unwrap(); // total changed mid-stream
        let dst = std::env::temp_dir().join("fedflare_recv_file_total.bin");
        let err = b.recv_file(&dst).unwrap_err();
        assert!(err.to_string().contains("inconsistent total"), "{err}");
        let _ = std::fs::remove_file(&dst);

        // out-of-range seq and zero total are rejected too
        let (mut raw, b) = inproc::pair(64, "sfile");
        let mut b = Messenger::new(Box::new(b), 1024, 2);
        raw.send(mk(7, 3)).unwrap();
        assert!(b.recv_file(&dst).is_err());
        let (mut raw, b) = inproc::pair(64, "zfile");
        let mut b = Messenger::new(Box::new(b), 1024, 2);
        raw.send(mk(0, 0)).unwrap();
        assert!(b.recv_file(&dst).is_err());
        let _ = std::fs::remove_file(&dst);
    }

    #[test]
    fn large_payload_streams_with_small_window() {
        // 2 MB through a 64-frame window of 4 kB chunks: sender must block
        // on backpressure; a concurrent receiver drains it.
        let (mut a, mut b) = pair(4096);
        let data = vec![0xABu8; 2 << 20];
        let expected = data.clone();
        let recv = std::thread::spawn(move || {
            let got = b.recv().unwrap();
            match got {
                Received::Bytes(v) => v,
                _ => panic!("wrong kind"),
            }
        });
        a.send_bytes(&data).unwrap();
        let got = recv.join().unwrap();
        assert_eq!(got.len(), expected.len());
        assert_eq!(got, expected);
    }

    #[test]
    fn tracked_memory_returns_to_baseline() {
        let before = crate::util::mem::tracked_bytes();
        {
            let (mut a, mut b) = pair(512);
            let data = vec![1u8; 100_000];
            let h = std::thread::spawn(move || {
                let r = b.recv().unwrap();
                drop(r);
            });
            a.send_bytes(&data).unwrap();
            h.join().unwrap();
        }
        assert_eq!(crate::util::mem::tracked_bytes(), before);
    }
}
