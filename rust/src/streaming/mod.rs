//! Object / blob / file streaming over the SFM frame layer (the paper's
//! four "streaming API variations": byte, blob, file, object).
//!
//! [`Messenger`] is what the coordinator/executor layers actually hold: it
//! owns a [`Driver`], allocates stream ids, chunks outgoing payloads
//! (1 MB default), reassembles incoming ones, and converts to/from
//! [`FlMessage`]. Send/receive of a 128 MB model and of a 40-byte control
//! message go through the identical code path — only the chunk count
//! differs.
//!
//! Object streams use **wire format v2** (tensor-granular records): the
//! sender encodes one tensor record at a time via
//! [`crate::message::FrameIter`] instead of materializing the payload,
//! and [`Messenger::recv_msg_stream`] hands each decoded tensor to a
//! callback the moment its frames arrive — the transport half of
//! fold-as-frames-arrive aggregation. [`Messenger::send_msg_v1`] keeps
//! the legacy blob format for compatibility, and every receive path
//! accepts both.

use std::io::{Read, Write};
use std::path::Path;

use crate::message::{FlMessage, FrameIter, MessageError};
use crate::sfm::{
    chunk_frames, Driver, Frame, Reassembler, RecordAssembler, SfmError, FLAG_FIRST, FLAG_LAST,
};
use crate::tensor::{RecordEnc, Tensor, TensorDict};
use crate::util::mem;
use crate::util::pool::{self, Payload};

/// Frames coalesced per [`crate::sfm::Driver::send_batch`] window on the
/// object send path — over TCP each window becomes one writev train.
const SEND_BATCH: usize = 16;

/// Application payload tags carried in the SFM `kind` field.
pub const KIND_BYTES: u16 = 0;
pub const KIND_BLOB: u16 = 1;
pub const KIND_OBJECT: u16 = 2;
pub const KIND_FILE: u16 = 3;
/// Object stream in wire format v2 (self-delimiting tensor records).
pub const KIND_OBJECT_V2: u16 = 4;

/// Streaming-layer errors.
#[derive(Debug, thiserror::Error)]
pub enum StreamError {
    #[error(transparent)]
    Sfm(#[from] SfmError),
    #[error("message: {0}")]
    Message(#[from] MessageError),
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("protocol: {0}")]
    Protocol(String),
}

/// A received payload, tagged with its stream kind.
#[derive(Debug)]
pub enum Received {
    Bytes(Vec<u8>),
    Blob(Vec<u8>),
    Object(FlMessage),
    /// File content held as bytes (use [`Messenger::recv_file`] to spool
    /// to disk instead).
    File(Vec<u8>),
}

/// Duplex streaming endpoint over any SFM driver.
pub struct Messenger {
    driver: Box<dyn Driver>,
    reasm: Reassembler,
    chunk_bytes: usize,
    next_stream: u64,
    /// Mid-message state carried across [`Messenger::recv_msg_nonblocking`]
    /// calls (a v2 object stream only partially arrived).
    inflight: Option<InflightMsg>,
    /// Running transfer counters (bytes of payload, not counting headers).
    pub sent_bytes: u64,
    pub recv_bytes: u64,
}

/// A v2 object message being assembled across nonblocking receive calls.
struct InflightMsg {
    asm: RecordAssembler,
    head: Option<FlMessage>,
    declared: usize,
    names: std::collections::BTreeSet<String>,
    body: TensorDict,
}

impl Messenger {
    /// `tag` disambiguates stream ids between endpoints (e.g. client idx).
    pub fn new(driver: Box<dyn Driver>, chunk_bytes: usize, tag: u32) -> Messenger {
        Messenger {
            driver,
            reasm: Reassembler::new(),
            chunk_bytes,
            next_stream: (tag as u64) << 32,
            inflight: None,
            sent_bytes: 0,
            recv_bytes: 0,
        }
    }

    pub fn driver_name(&self) -> String {
        self.driver.name()
    }

    /// Bound reassembly memory held for vanished peers: stale partial
    /// streams are evicted per `policy` and counted in
    /// [`mem::evicted_bytes`](crate::util::mem::evicted_bytes).
    pub fn set_reassembly_policy(&mut self, policy: crate::sfm::EvictionPolicy) {
        self.reasm.set_policy(policy);
    }

    fn alloc_stream(&mut self) -> u64 {
        self.next_stream += 1;
        self.next_stream
    }

    /// Stream raw bytes (`kind` selects byte/blob semantics upstream).
    /// Counters move only after each frame is accepted by the driver, so
    /// a failed send does not overstate traffic.
    fn send_tagged(&mut self, kind: u16, payload: &[u8]) -> Result<(), StreamError> {
        let stream = self.alloc_stream();
        // Stage-and-send: the outgoing message is materialized once (this
        // is the "model + runtime space" the paper's Fig-5 memory math
        // counts on the sender side), then chunked out.
        mem::track_alloc(payload.len());
        let result = (|| {
            for frame in chunk_frames(kind, stream, payload, self.chunk_bytes) {
                let n = frame.payload.len() as u64;
                self.driver.send(frame)?;
                self.sent_bytes += n;
            }
            Ok(())
        })();
        mem::track_free(payload.len());
        result
    }

    /// Paper variation 1: raw byte streaming.
    pub fn send_bytes(&mut self, payload: &[u8]) -> Result<(), StreamError> {
        self.send_tagged(KIND_BYTES, payload)
    }

    /// Paper variation 2: blob streaming (semantically one opaque value).
    pub fn send_blob(&mut self, payload: &[u8]) -> Result<(), StreamError> {
        self.send_tagged(KIND_BLOB, payload)
    }

    /// Paper variation 4: object streaming — the FL workhorse. Uses wire
    /// format v2: frames are cut lazily from one tensor record at a time
    /// ([`FrameIter`]), so the sender never stages a second copy of the
    /// payload — peak extra memory is O(largest tensor + chunk).
    pub fn send_msg(&mut self, msg: &FlMessage) -> Result<(), StreamError> {
        self.send_msg_enc(msg, RecordEnc::Raw)
    }

    /// [`Messenger::send_msg`] with an explicit record transport encoding
    /// (e.g. [`RecordEnc::F16`] to halve f32 bytes on the wire).
    pub fn send_msg_enc(&mut self, msg: &FlMessage, enc: RecordEnc) -> Result<(), StreamError> {
        let stream = self.alloc_stream();
        // Coalesce ready frames into small windows: one driver handoff
        // (over TCP, one writev train) per window instead of one per
        // frame. Counters move only after the driver accepts a window.
        let mut batch: Vec<Frame> = Vec::with_capacity(SEND_BATCH);
        let mut batch_bytes = 0u64;
        for frame in FrameIter::new(msg, KIND_OBJECT_V2, stream, self.chunk_bytes, enc) {
            batch_bytes += frame.payload.len() as u64;
            batch.push(frame);
            if batch.len() == SEND_BATCH {
                self.driver.send_batch(std::mem::take(&mut batch))?;
                self.sent_bytes += batch_bytes;
                batch_bytes = 0;
                batch.reserve(SEND_BATCH);
            }
        }
        if !batch.is_empty() {
            self.driver.send_batch(batch)?;
            self.sent_bytes += batch_bytes;
        }
        Ok(())
    }

    /// Legacy v1 object send: materialize the whole blob, then chunk it
    /// (kept for compatibility tests and old peers; costs a full extra
    /// payload copy on the sender).
    pub fn send_msg_v1(&mut self, msg: &FlMessage) -> Result<(), StreamError> {
        let bytes = msg.to_bytes();
        self.send_tagged(KIND_OBJECT, &bytes)
    }

    /// Paper variation 3: file streaming. Reads and sends chunk-by-chunk,
    /// never holding the whole file in memory.
    pub fn send_file(&mut self, path: &Path) -> Result<(), StreamError> {
        let meta = std::fs::metadata(path)?;
        let size = meta.len() as usize;
        let stream = self.alloc_stream();
        let total = size.div_ceil(self.chunk_bytes).max(1) as u32;
        let mut file = std::fs::File::open(path)?;
        for seq in 0..total {
            let want = if seq == total - 1 && size > 0 {
                size - seq as usize * self.chunk_bytes
            } else if size == 0 {
                0
            } else {
                self.chunk_bytes
            };
            // read straight into a pooled chunk buffer (a pool hit after
            // the first frame) — no reusable scratch + per-frame to_vec
            let mut pb = pool::take(self.chunk_bytes);
            pb.vec_mut().resize(want, 0);
            file.read_exact(&mut pb.vec_mut()[..want])?;
            let mut flags = 0;
            if seq == 0 {
                flags |= FLAG_FIRST;
            }
            if seq == total - 1 {
                flags |= FLAG_LAST;
            }
            self.driver.send(Frame {
                flags,
                kind: KIND_FILE,
                job: 0,
                stream,
                seq,
                total,
                payload: pb.freeze(),
            })?;
            self.sent_bytes += want as u64;
        }
        Ok(())
    }

    /// Block until the next complete payload arrives (any kind).
    pub fn recv(&mut self) -> Result<Received, StreamError> {
        loop {
            let frame = self.driver.recv()?;
            let n = frame.payload.len() as u64;
            let done = self.reasm.push(frame)?;
            self.recv_bytes += n;
            if let Some((_stream, kind, payload)) = done {
                // ownership transferred to the caller; release tracking here
                mem::track_free(payload.len());
                return Ok(match kind {
                    KIND_BYTES => Received::Bytes(payload),
                    KIND_BLOB => Received::Blob(payload),
                    KIND_OBJECT => Received::Object(FlMessage::from_bytes(&payload)?),
                    KIND_OBJECT_V2 => Received::Object(FlMessage::from_v2_bytes(&payload)?),
                    KIND_FILE => Received::File(payload),
                    other => {
                        return Err(StreamError::Protocol(format!(
                            "unknown stream kind {other}"
                        )))
                    }
                });
            }
        }
    }

    /// Block until the next [`FlMessage`] arrives (errors on other kinds —
    /// the FL protocol only exchanges objects). Built on
    /// [`Messenger::recv_msg_stream`], so a v2 stream is assembled tensor
    /// by tensor without ever staging the full payload bytes.
    pub fn recv_msg(&mut self) -> Result<FlMessage, StreamError> {
        let mut body = TensorDict::new();
        let mut head = self.recv_msg_stream(|_h, name, t| {
            body.insert(name, t);
            Ok(())
        })?;
        head.body = body;
        Ok(head)
    }

    /// Incremental object receive — the tensor-granular API. Blocks until
    /// one whole object stream has arrived, invoking `on_tensor(header,
    /// name, tensor)` for **each tensor record the moment its frames
    /// complete** (v2 streams; out-of-order frames within the in-flight
    /// window are handled by [`RecordAssembler`]). Returns the body-less
    /// header message. The v2 header record always precedes tensor
    /// records, so the callback can read routing/meta (e.g. aggregation
    /// weights) from its first argument.
    ///
    /// Legacy v1 blob streams are buffered whole, then drained through the
    /// same callback — identical semantics, v1 memory cost.
    ///
    /// Frames of a different stream or a non-object kind arriving
    /// mid-receive are protocol errors (object exchanges are strictly
    /// sequential per peer, like `recv_file`).
    pub fn recv_msg_stream(
        &mut self,
        mut on_tensor: impl FnMut(&FlMessage, String, Tensor) -> Result<(), StreamError>,
    ) -> Result<FlMessage, StreamError> {
        let first = self.driver.recv()?;
        let stream = first.stream;
        match first.kind {
            KIND_OBJECT_V2 => {
                let mut asm = RecordAssembler::new();
                let mut head: Option<FlMessage> = None;
                let mut declared = 0usize;
                // distinct record names — duplicates are a protocol error,
                // matching `FlMessage::from_v2_bytes` (last-insert-wins
                // would silently drop a tensor)
                let mut names = std::collections::BTreeSet::new();
                let mut frame = first;
                loop {
                    let n = frame.payload.len() as u64;
                    let records = asm.push(frame)?;
                    self.recv_bytes += n;
                    for rec in records {
                        match &head {
                            None => {
                                let (h, count) = FlMessage::parse_v2_header(&rec)?;
                                declared = count;
                                head = Some(h);
                            }
                            Some(h) => {
                                let (name, t) = tensor_record(&rec)?;
                                if !names.insert(name.clone()) {
                                    return Err(StreamError::Protocol(format!(
                                        "v2 stream: duplicate tensor record '{name}'"
                                    )));
                                }
                                on_tensor(h, name, t)?;
                            }
                        }
                    }
                    if asm.is_done() {
                        break;
                    }
                    frame = self.driver.recv()?;
                }
                let head = head.ok_or_else(|| {
                    StreamError::Protocol("v2 stream ended without a header record".into())
                })?;
                if names.len() != declared {
                    return Err(StreamError::Protocol(format!(
                        "v2 stream: header declared {declared} tensors, got {}",
                        names.len()
                    )));
                }
                Ok(head)
            }
            KIND_OBJECT => {
                // v1 blob: buffer the stream, then drain tensors through
                // the same callback
                let mut frame = first;
                loop {
                    if frame.stream != stream {
                        return Err(StreamError::Protocol(format!(
                            "stream {} interleaves object stream {stream}",
                            frame.stream
                        )));
                    }
                    let n = frame.payload.len() as u64;
                    let done = self.reasm.push(frame)?;
                    self.recv_bytes += n;
                    if let Some((_, _, payload)) = done {
                        mem::track_free(payload.len());
                        let msg = FlMessage::from_bytes(&payload)?;
                        drop(payload);
                        let mut head = msg;
                        let body = std::mem::take(&mut head.body);
                        for (name, t) in body.into_entries() {
                            on_tensor(&head, name, t)?;
                        }
                        return Ok(head);
                    }
                    frame = self.driver.recv()?;
                }
            }
            other => Err(StreamError::Protocol(format!(
                "expected object stream, got kind {other}"
            ))),
        }
    }

    /// Receive a file stream directly to disk, writing chunks as the
    /// contiguous prefix grows (out-of-order chunks are buffered).
    ///
    /// The first frame latches the stream id and chunk count; frames from
    /// any other stream — or frames whose `total` disagrees — are a
    /// protocol error rather than silent corruption of the output file.
    pub fn recv_file(&mut self, out: &Path) -> Result<u64, StreamError> {
        let mut file = std::fs::File::create(out)?;
        let mut pending: std::collections::BTreeMap<u32, Payload> = Default::default();
        let mut latched: Option<(u64, u16, u32)> = None;
        let mut next_seq = 0u32;
        let mut written = 0u64;
        loop {
            let frame = self.driver.recv()?;
            if frame.kind != KIND_FILE {
                return Err(StreamError::Protocol(
                    "interleaved non-file stream during recv_file".into(),
                ));
            }
            let (_, _, total) = crate::sfm::latch_frame(&mut latched, &frame, "file")?;
            self.recv_bytes += frame.payload.len() as u64;
            pending.insert(frame.seq, frame.payload);
            while let Some(chunk) = pending.remove(&next_seq) {
                file.write_all(&chunk)?;
                written += chunk.len() as u64;
                next_seq += 1;
            }
            if next_seq == total {
                file.flush()?;
                return Ok(written);
            }
        }
    }

    /// Non-blocking [`Messenger::recv_msg`]: drain whatever frames the
    /// driver has buffered and return `Ok(Some(msg))` once a whole object
    /// message has arrived, `Ok(None)` while one is still (or not yet) in
    /// flight. Mid-message state persists across calls, so a control
    /// dispatcher can interleave many messengers on one thread without
    /// parking on any of them. Object kinds only — the control protocol
    /// exchanges nothing else.
    pub fn recv_msg_nonblocking(&mut self) -> Result<Option<FlMessage>, StreamError> {
        loop {
            let Some(frame) = self.driver.try_recv()? else {
                return Ok(None);
            };
            let n = frame.payload.len() as u64;
            match frame.kind {
                KIND_OBJECT_V2 => {
                    let fl = self.inflight.get_or_insert_with(|| InflightMsg {
                        asm: RecordAssembler::new(),
                        head: None,
                        declared: 0,
                        names: Default::default(),
                        body: TensorDict::new(),
                    });
                    let records = fl.asm.push(frame)?;
                    self.recv_bytes += n;
                    for rec in records {
                        match &fl.head {
                            None => {
                                let (h, count) = FlMessage::parse_v2_header(&rec)?;
                                fl.declared = count;
                                fl.head = Some(h);
                            }
                            Some(_) => {
                                let (name, t) = tensor_record(&rec)?;
                                if !fl.names.insert(name.clone()) {
                                    return Err(StreamError::Protocol(format!(
                                        "v2 stream: duplicate tensor record '{name}'"
                                    )));
                                }
                                fl.body.insert(name, t);
                            }
                        }
                    }
                    if fl.asm.is_done() {
                        let fl = self.inflight.take().expect("inflight present");
                        let mut head = fl.head.ok_or_else(|| {
                            StreamError::Protocol(
                                "v2 stream ended without a header record".into(),
                            )
                        })?;
                        if fl.names.len() != fl.declared {
                            return Err(StreamError::Protocol(format!(
                                "v2 stream: header declared {} tensors, got {}",
                                fl.declared,
                                fl.names.len()
                            )));
                        }
                        head.body = fl.body;
                        return Ok(Some(head));
                    }
                }
                KIND_OBJECT => {
                    // legacy v1 blob: partials persist in the reassembler
                    let done = self.reasm.push(frame)?;
                    self.recv_bytes += n;
                    if let Some((_, _, payload)) = done {
                        mem::track_free(payload.len());
                        return Ok(Some(FlMessage::from_bytes(&payload)?));
                    }
                }
                other => {
                    return Err(StreamError::Protocol(format!(
                        "expected object stream, got kind {other}"
                    )))
                }
            }
        }
    }

    /// Send the end-of-job control message.
    pub fn send_bye(&mut self) -> Result<(), StreamError> {
        self.send_msg(&FlMessage::bye())
    }
}

/// Decode one v2 tensor record, mapping byte errors into stream errors.
fn tensor_record(rec: &[u8]) -> Result<(String, Tensor), StreamError> {
    crate::tensor::decode_record(rec).map_err(|e| StreamError::Message(MessageError::Bytes(e)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sfm::inproc;
    use crate::tensor::{Tensor, TensorDict};

    fn pair(chunk: usize) -> (Messenger, Messenger) {
        let (a, b) = inproc::pair(64, "m");
        (
            Messenger::new(Box::new(a), chunk, 1),
            Messenger::new(Box::new(b), chunk, 2),
        )
    }

    #[test]
    fn object_roundtrip_multi_chunk() {
        let (mut a, mut b) = pair(256);
        let mut body = TensorDict::new();
        body.insert("w", Tensor::f32(vec![1000], vec![0.5; 1000])); // ~4 kB
        let msg = FlMessage::task("train", 2, body);
        a.send_msg(&msg).unwrap();
        let got = b.recv_msg().unwrap();
        assert_eq!(got, msg);
        assert!(a.sent_bytes >= 4000);
        assert_eq!(a.sent_bytes, b.recv_bytes);
    }

    #[test]
    fn bytes_blob_kinds_distinguished() {
        let (mut a, mut b) = pair(64);
        a.send_bytes(&[1, 2, 3]).unwrap();
        a.send_blob(&[4, 5]).unwrap();
        assert!(matches!(b.recv().unwrap(), Received::Bytes(v) if v == vec![1,2,3]));
        assert!(matches!(b.recv().unwrap(), Received::Blob(v) if v == vec![4,5]));
    }

    #[test]
    fn recv_msg_rejects_wrong_kind() {
        let (mut a, mut b) = pair(64);
        a.send_bytes(&[9]).unwrap();
        assert!(b.recv_msg().is_err());
    }

    #[test]
    fn empty_message_roundtrip() {
        let (mut a, mut b) = pair(1024);
        a.send_msg(&FlMessage::bye()).unwrap();
        let got = b.recv_msg().unwrap();
        assert_eq!(got.kind, crate::message::Kind::Bye);
    }

    #[test]
    fn file_streaming_roundtrip() {
        let dir = std::env::temp_dir();
        let src = dir.join("fedflare_test_src.bin");
        let dst = dir.join("fedflare_test_dst.bin");
        let data: Vec<u8> = (0..30_000u32).map(|i| (i % 253) as u8).collect();
        std::fs::write(&src, &data).unwrap();

        let (mut a, mut b) = pair(1024);
        let send = {
            let src = src.clone();
            std::thread::spawn(move || {
                a.send_file(&src).unwrap();
                a
            })
        };
        let written = b.recv_file(&dst).unwrap();
        send.join().unwrap();
        assert_eq!(written, data.len() as u64);
        assert_eq!(std::fs::read(&dst).unwrap(), data);
        let _ = std::fs::remove_file(&src);
        let _ = std::fs::remove_file(&dst);
    }

    #[test]
    fn recv_file_rejects_interleaved_second_stream() {
        use crate::sfm::{Driver, Frame};
        let (mut raw, b) = inproc::pair(64, "ifile");
        let mut b = Messenger::new(Box::new(b), 1024, 2);
        let mk = |stream: u64, seq: u32, total: u32| Frame {
            flags: 0,
            kind: KIND_FILE,
            job: 0,
            stream,
            seq,
            total,
            payload: vec![seq as u8; 16].into(),
        };
        raw.send(mk(1, 0, 3)).unwrap();
        raw.send(mk(2, 0, 3)).unwrap(); // second stream interleaves
        let dst = std::env::temp_dir().join("fedflare_recv_file_interleave.bin");
        let err = b.recv_file(&dst).unwrap_err();
        assert!(
            err.to_string().contains("interleaved file stream"),
            "{err}"
        );
        let _ = std::fs::remove_file(&dst);
    }

    #[test]
    fn recv_file_rejects_inconsistent_total() {
        use crate::sfm::{Driver, Frame};
        let (mut raw, b) = inproc::pair(64, "tfile");
        let mut b = Messenger::new(Box::new(b), 1024, 2);
        let mk = |seq: u32, total: u32| Frame {
            flags: 0,
            kind: KIND_FILE,
            job: 0,
            stream: 9,
            seq,
            total,
            payload: vec![seq as u8; 16].into(),
        };
        raw.send(mk(0, 3)).unwrap();
        raw.send(mk(1, 4)).unwrap(); // total changed mid-stream
        let dst = std::env::temp_dir().join("fedflare_recv_file_total.bin");
        let err = b.recv_file(&dst).unwrap_err();
        assert!(err.to_string().contains("inconsistent total"), "{err}");
        let _ = std::fs::remove_file(&dst);

        // out-of-range seq and zero total are rejected too
        let (mut raw, b) = inproc::pair(64, "sfile");
        let mut b = Messenger::new(Box::new(b), 1024, 2);
        raw.send(mk(7, 3)).unwrap();
        assert!(b.recv_file(&dst).is_err());
        let (mut raw, b) = inproc::pair(64, "zfile");
        let mut b = Messenger::new(Box::new(b), 1024, 2);
        raw.send(mk(0, 0)).unwrap();
        assert!(b.recv_file(&dst).is_err());
        let _ = std::fs::remove_file(&dst);
    }

    #[test]
    fn v1_and_v2_object_sends_both_decode() {
        let (mut a, mut b) = pair(128);
        let mut body = TensorDict::new();
        body.insert("w", Tensor::f32(vec![300], vec![0.25; 300]));
        body.insert("ids", Tensor::i32(vec![2], vec![5, -6]));
        let msg = FlMessage::task("train", 1, body);
        a.send_msg(&msg).unwrap(); // v2
        a.send_msg_v1(&msg).unwrap(); // legacy blob
        assert_eq!(b.recv_msg().unwrap(), msg);
        assert_eq!(b.recv_msg().unwrap(), msg);
        assert_eq!(a.sent_bytes, b.recv_bytes);
    }

    #[test]
    fn f16_transport_halves_wire_bytes() {
        let (mut a, mut b) = pair(256);
        let mut body = TensorDict::new();
        body.insert("w", Tensor::f32(vec![1000], vec![0.5; 1000]));
        let msg = FlMessage::task("train", 0, body);
        a.send_msg_enc(&msg, crate::tensor::RecordEnc::F16).unwrap();
        let f16_bytes = a.sent_bytes;
        let got = b.recv_msg().unwrap();
        assert_eq!(got.body.get("w").unwrap().as_f32().unwrap(), &[0.5; 1000]);
        a.send_msg(&msg).unwrap();
        let raw_bytes = a.sent_bytes - f16_bytes;
        b.recv_msg().unwrap();
        assert!(
            (f16_bytes as f64) < 0.6 * raw_bytes as f64,
            "f16 {f16_bytes} vs raw {raw_bytes}"
        );
    }

    #[test]
    fn recv_msg_stream_yields_tensors_incrementally_with_header_first() {
        let (mut a, mut b) = pair(64);
        let mut body = TensorDict::new();
        body.insert("a", Tensor::f32(vec![50], vec![1.0; 50]));
        body.insert("b", Tensor::f32(vec![50], vec![2.0; 50]));
        body.insert("c", Tensor::i32(vec![3], vec![7, 8, 9]));
        let msg = FlMessage::result("train", 3, "site-9", body.clone())
            .with_meta("n_samples", crate::util::json::Json::num(40.0));
        let send = std::thread::spawn(move || {
            a.send_msg(&msg).unwrap();
            a
        });
        let mut seen = Vec::new();
        let head = b
            .recv_msg_stream(|h, name, t| {
                // header meta is available before any tensor arrives
                assert_eq!(h.metric("n_samples"), Some(40.0));
                assert_eq!(h.client, "site-9");
                assert!(h.body.is_empty());
                seen.push((name, t));
                Ok(())
            })
            .unwrap();
        send.join().unwrap();
        assert_eq!(head.round, 3);
        // sender iterates in name order; the in-order transport preserves it
        assert_eq!(
            seen.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
            vec!["a", "b", "c"]
        );
        let mut rebuilt = TensorDict::new();
        for (n, t) in seen {
            rebuilt.insert(n, t);
        }
        assert_eq!(rebuilt, body);
    }

    #[test]
    fn recv_msg_stream_handles_v1_blob_streams() {
        let (mut a, mut b) = pair(64);
        let mut body = TensorDict::new();
        body.insert("w", Tensor::f32(vec![20], vec![0.5; 20]));
        let msg = FlMessage::result("train", 0, "c1", body.clone());
        a.send_msg_v1(&msg).unwrap();
        let mut names = Vec::new();
        let head = b
            .recv_msg_stream(|_h, name, _t| {
                names.push(name);
                Ok(())
            })
            .unwrap();
        assert_eq!(head.client, "c1");
        assert_eq!(names, vec!["w"]);
    }

    #[test]
    fn recv_msg_nonblocking_assembles_across_calls() {
        let (mut a, mut b) = pair(64);
        // nothing in flight: None, not a block
        assert!(b.recv_msg_nonblocking().unwrap().is_none());
        let mut body = TensorDict::new();
        body.insert("w", Tensor::f32(vec![100], vec![1.5; 100])); // several chunks
        let msg = FlMessage::task("train", 4, body);
        a.send_msg(&msg).unwrap();
        // frames are already buffered in the channel: polling drains them
        // (possibly over multiple calls) until the message completes
        let t0 = std::time::Instant::now();
        let got = loop {
            if let Some(m) = b.recv_msg_nonblocking().unwrap() {
                break m;
            }
            assert!(t0.elapsed() < std::time::Duration::from_secs(2));
            std::thread::sleep(std::time::Duration::from_millis(1));
        };
        assert_eq!(got, msg);
        // v1 blobs assemble through the same call
        a.send_msg_v1(&msg).unwrap();
        let got = loop {
            if let Some(m) = b.recv_msg_nonblocking().unwrap() {
                break m;
            }
            assert!(t0.elapsed() < std::time::Duration::from_secs(2));
        };
        assert_eq!(got, msg);
        // peer drop surfaces as Closed, not a silent forever-None
        drop(a);
        assert!(b.recv_msg_nonblocking().is_err());
    }

    #[test]
    fn byte_counters_untouched_when_send_fails() {
        // a closed peer makes every send fail: counters must not move
        let (a, b) = inproc::pair(4, "cnt");
        let mut tx = Messenger::new(Box::new(a), 64, 1);
        drop(b);
        let err = tx.send_bytes(&[0u8; 4096]).unwrap_err();
        assert!(matches!(err, StreamError::Sfm(crate::sfm::SfmError::Closed)));
        assert_eq!(tx.sent_bytes, 0);
        let mut body = TensorDict::new();
        body.insert("w", Tensor::f32(vec![64], vec![1.0; 64]));
        assert!(tx.send_msg(&FlMessage::task("t", 0, body)).is_err());
        assert_eq!(tx.sent_bytes, 0);
    }

    #[test]
    fn large_payload_streams_with_small_window() {
        // 2 MB through a 64-frame window of 4 kB chunks: sender must block
        // on backpressure; a concurrent receiver drains it.
        let (mut a, mut b) = pair(4096);
        let data = vec![0xABu8; 2 << 20];
        let expected = data.clone();
        let recv = std::thread::spawn(move || {
            let got = b.recv().unwrap();
            match got {
                Received::Bytes(v) => v,
                _ => panic!("wrong kind"),
            }
        });
        a.send_bytes(&data).unwrap();
        let got = recv.join().unwrap();
        assert_eq!(got.len(), expected.len());
        assert_eq!(got, expected);
    }

    #[test]
    fn tracked_memory_returns_to_baseline() {
        let before = crate::util::mem::tracked_bytes();
        {
            let (mut a, mut b) = pair(512);
            let data = vec![1u8; 100_000];
            let h = std::thread::spawn(move || {
                let r = b.recv().unwrap();
                drop(r);
            });
            a.send_bytes(&data).unwrap();
            h.join().unwrap();
        }
        assert_eq!(crate::util::mem::tracked_bytes(), before);
    }
}
