//! `TensorDict` — the model/update payload type that flows through the FL
//! system (what the paper calls the "model" in `FLModel(params=...)`).
//!
//! An ordered map from parameter name to a dense tensor (f32 or i32), with
//! a compact binary wire format (what the streaming layer chunks), a f16
//! transport encoding for the quantization filter, and the in-place math
//! the aggregator hot loop needs (`axpy`, `scale`).

use std::collections::BTreeMap;

use crate::util::bytes::{self, ByteError, Reader, Writer};

/// Element type of a tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn from_str(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "i32" => Some(DType::I32),
            _ => None,
        }
    }
    pub fn as_str(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        }
    }
    fn tag(&self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::I32 => 1,
        }
    }
    fn from_tag(t: u8) -> Option<DType> {
        match t {
            0 => Some(DType::F32),
            1 => Some(DType::I32),
            _ => None,
        }
    }
}

/// Dense tensor storage.
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    pub fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    pub fn dtype(&self) -> DType {
        match self {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
        }
    }
}

/// A named dense tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor {
            shape,
            data: Data::F32(data),
        }
    }
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor {
            shape,
            data: Data::I32(data),
        }
    }
    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::f32(shape, vec![0.0; n])
    }
    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::f32(vec![], vec![v])
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
    pub fn dtype(&self) -> DType {
        self.data.dtype()
    }
    /// Payload bytes (excluding name/shape header).
    pub fn byte_size(&self) -> usize {
        self.data.len() * 4
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match &self.data {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_f32_mut(&mut self) -> Option<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_i32(&self) -> Option<&[i32]> {
        match &self.data {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
    /// First element as f32 (for scalar metric outputs).
    pub fn item(&self) -> f32 {
        match &self.data {
            Data::F32(v) => v[0],
            Data::I32(v) => v[0] as f32,
        }
    }
}

/// Ordered name → tensor map. Iteration order is the sorted name order —
/// the same order the AOT manifest records, so marshaling is positional.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TensorDict {
    map: BTreeMap<String, Tensor>,
}

impl TensorDict {
    pub fn new() -> TensorDict {
        TensorDict::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, t: Tensor) {
        self.map.insert(name.into(), t);
    }
    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.map.get(name)
    }
    pub fn get_mut(&mut self, name: &str) -> Option<&mut Tensor> {
        self.map.get_mut(name)
    }
    pub fn remove(&mut self, name: &str) -> Option<Tensor> {
        self.map.remove(name)
    }
    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }
    pub fn len(&self) -> usize {
        self.map.len()
    }
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }
    /// Consume the dict, yielding owned (name, tensor) pairs in name order
    /// (lets the streaming receive path hand tensors off without cloning).
    pub fn into_entries(self) -> impl Iterator<Item = (String, Tensor)> {
        self.map.into_iter()
    }
    pub fn iter(&self) -> impl Iterator<Item = (&str, &Tensor)> {
        self.map.iter().map(|(k, v)| (k.as_str(), v))
    }
    pub fn iter_mut(&mut self) -> impl Iterator<Item = (&str, &mut Tensor)> {
        self.map.iter_mut().map(|(k, v)| (k.as_str(), v))
    }

    /// Total payload bytes across tensors.
    pub fn byte_size(&self) -> usize {
        self.map.values().map(|t| t.byte_size()).sum()
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.map.values().map(|t| t.numel()).sum()
    }

    /// Sub-dict with only the named keys (PEFT: communicate adapters only).
    pub fn subset(&self, names: &[String]) -> TensorDict {
        let mut out = TensorDict::new();
        for n in names {
            if let Some(t) = self.map.get(n) {
                out.insert(n.clone(), t.clone());
            }
        }
        out
    }

    /// Merge `other`'s tensors into self (overwrites same-name entries).
    pub fn merge(&mut self, other: &TensorDict) {
        for (k, v) in other.iter() {
            self.map.insert(k.to_string(), v.clone());
        }
    }

    // ------------------------------------------------------------- math

    /// `self += alpha * other` over all matching f32 tensors (i32 tensors
    /// are passed through untouched, mirroring [`TensorDict::scale`]).
    /// Panics on missing names or length mismatch (caller validates via
    /// [`TensorDict::same_schema`]).
    pub fn axpy(&mut self, alpha: f32, other: &TensorDict) {
        for (name, t) in self.map.iter_mut() {
            let o = other
                .map
                .get(name)
                .unwrap_or_else(|| panic!("axpy: missing tensor {name}"));
            let (Some(a), Some(b)) = (t.as_f32_mut(), o.as_f32()) else {
                continue; // non-f32: not aggregatable, leave as-is
            };
            assert_eq!(a.len(), b.len(), "axpy: length mismatch for {name}");
            axpy_slice(a, alpha, b);
        }
    }

    /// `self += c * (other - self)` over all matching f32 tensors — the
    /// running-weighted-mean fold of the streaming aggregator
    /// (`agg += (w_i / W_cum) * (x_i - agg)`). i32 tensors pass through
    /// untouched, mirroring [`TensorDict::axpy`]. Panics on missing names
    /// or length mismatch (caller validates via
    /// [`TensorDict::same_schema`]).
    pub fn lerp(&mut self, c: f32, other: &TensorDict) {
        for (name, t) in self.map.iter_mut() {
            let o = other
                .map
                .get(name)
                .unwrap_or_else(|| panic!("lerp: missing tensor {name}"));
            let (Some(a), Some(b)) = (t.as_f32_mut(), o.as_f32()) else {
                continue; // non-f32: not aggregatable, leave as-is
            };
            assert_eq!(a.len(), b.len(), "lerp: length mismatch for {name}");
            lerp_slice(a, c, b);
        }
    }

    /// `self *= alpha` over all f32 tensors.
    pub fn scale(&mut self, alpha: f32) {
        for t in self.map.values_mut() {
            if let Some(a) = t.as_f32_mut() {
                for x in a.iter_mut() {
                    *x *= alpha;
                }
            }
        }
    }

    /// Zeroed clone (same schema, f32 zeros / i32 zeros).
    pub fn zeros_like(&self) -> TensorDict {
        let mut out = TensorDict::new();
        for (k, t) in self.iter() {
            let z = match &t.data {
                Data::F32(v) => Tensor::f32(t.shape.clone(), vec![0.0; v.len()]),
                Data::I32(v) => Tensor::i32(t.shape.clone(), vec![0; v.len()]),
            };
            out.insert(k.to_string(), z);
        }
        out
    }

    /// True if `other` has exactly the same names/shapes/dtypes.
    pub fn same_schema(&self, other: &TensorDict) -> bool {
        self.len() == other.len()
            && self.iter().all(|(k, t)| {
                other
                    .get(k)
                    .map(|o| o.shape == t.shape && o.dtype() == t.dtype())
                    .unwrap_or(false)
            })
    }

    /// L2 norm over all f32 tensors (for DP clipping).
    pub fn l2_norm(&self) -> f64 {
        let mut acc = 0.0f64;
        for t in self.map.values() {
            if let Some(v) = t.as_f32() {
                for &x in v {
                    acc += (x as f64) * (x as f64);
                }
            }
        }
        acc.sqrt()
    }

    /// Max absolute difference vs another dict (test helper).
    pub fn max_abs_diff(&self, other: &TensorDict) -> f32 {
        let mut m = 0.0f32;
        for (k, t) in self.iter() {
            if let (Some(a), Some(b)) = (t.as_f32(), other.get(k).and_then(|o| o.as_f32())) {
                for (x, y) in a.iter().zip(b) {
                    m = m.max((x - y).abs());
                }
            }
        }
        m
    }

    // ----------------------------------------------------------- wire

    /// Serialize to the binary wire format:
    /// `u32 count | per tensor: str name, u8 dtype, u8 ndim, u32 dims.., u32 len, payload`.
    pub fn to_bytes(&self) -> Vec<u8> {
        // Exact encoded length (per tensor: str prefix + name + dtype +
        // ndim + dims + len prefix + 4 bytes/element) — no heuristic
        // padding, so the buffer never reallocates and never over-reserves.
        let cap = 4 + self
            .map
            .iter()
            .map(|(name, t)| 4 + name.len() + 1 + 1 + 4 * t.shape.len() + 4 + t.data.len() * 4)
            .sum::<usize>();
        let mut w = Writer::with_capacity(cap);
        w.u32(self.map.len() as u32);
        for (name, t) in &self.map {
            w.str(name);
            w.u8(t.dtype().tag());
            w.u8(t.shape.len() as u8);
            for &d in &t.shape {
                w.u32(d as u32);
            }
            match &t.data {
                Data::F32(v) => {
                    w.u32(v.len() as u32);
                    w.bytes(bytes::f32_slice_as_bytes(v));
                }
                Data::I32(v) => {
                    w.u32(v.len() as u32);
                    w.bytes(bytes::i32_slice_as_bytes(v));
                }
            }
        }
        w.into_vec()
    }

    pub fn from_bytes(buf: &[u8]) -> Result<TensorDict, ByteError> {
        let mut r = Reader::new(buf);
        let count = r.u32()? as usize;
        let mut out = TensorDict::new();
        for _ in 0..count {
            let name = r.str()?;
            let dtype = DType::from_tag(r.u8()?).ok_or(ByteError {
                offset: r.pos(),
                msg: "bad dtype tag".into(),
            })?;
            let ndim = r.u8()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(r.u32()? as usize);
            }
            let len = r.u32()? as usize;
            if shape.iter().product::<usize>() != len {
                return Err(ByteError {
                    offset: r.pos(),
                    msg: format!("tensor {name}: shape/len mismatch"),
                });
            }
            let raw = r.take(len * 4)?;
            let t = match dtype {
                DType::F32 => Tensor::f32(shape, bytes::bytes_to_f32_vec(raw)?),
                DType::I32 => Tensor::i32(shape, bytes::bytes_to_i32_vec(raw)?),
            };
            out.insert(name, t);
        }
        r.expect_end()?;
        Ok(out)
    }
}

// ------------------------------------------------------- wire v2 records
//
// Wire format v2 is tensor-granular: instead of one contiguous blob, a
// message is a sequence of self-delimiting records (each length-prefixed
// by the framing layer), one per named tensor. A record decodes on its
// own, so the receiver can reassemble and fold tensors one at a time —
// peak staging is O(largest tensor), not O(model).

/// Transport encoding of one v2 tensor record's payload bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RecordEnc {
    /// Raw little-endian element bytes (4 per element).
    #[default]
    Raw,
    /// IEEE half-precision packed payload (2 bytes per element; f32
    /// tensors only — i32 records fall back to raw). The decoder expands
    /// back to f32, so the dtype on both ends stays f32 and only the wire
    /// bytes halve.
    F16,
    /// Affine 8-bit quantization: an 8-byte `f32 scale | f32 min` prefix
    /// followed by one code byte per element (`x ≈ min + code * scale`).
    /// f32 tensors only — i32 records fall back to raw. Per-element
    /// dequantize error is bounded by `scale / 2 = (max - min) / 510`.
    Int8,
    /// Affine 4-bit quantization: the same 8-byte prefix followed by two
    /// codes per byte (low nibble first; an odd tail leaves the high
    /// nibble zero). Error bound is `scale / 2 = (max - min) / 30`.
    Int4,
}

impl RecordEnc {
    fn tag(&self) -> u8 {
        match self {
            RecordEnc::Raw => 0,
            RecordEnc::F16 => 1,
            RecordEnc::Int8 => 2,
            RecordEnc::Int4 => 3,
        }
    }
    fn from_tag(t: u8) -> Option<RecordEnc> {
        match t {
            0 => Some(RecordEnc::Raw),
            1 => Some(RecordEnc::F16),
            2 => Some(RecordEnc::Int8),
            3 => Some(RecordEnc::Int4),
            _ => None,
        }
    }
    /// Parse a config/CLI codec name.
    pub fn from_str(s: &str) -> Option<RecordEnc> {
        match s {
            "raw" | "f32" => Some(RecordEnc::Raw),
            "f16" => Some(RecordEnc::F16),
            "int8" => Some(RecordEnc::Int8),
            "int4" => Some(RecordEnc::Int4),
            _ => None,
        }
    }
    pub fn as_str(&self) -> &'static str {
        match self {
            RecordEnc::Raw => "raw",
            RecordEnc::F16 => "f16",
            RecordEnc::Int8 => "int8",
            RecordEnc::Int4 => "int4",
        }
    }
}

/// Encoded byte length of one tensor record's payload (without the
/// framing layer's u32 length prefix) — lets the sender compute the total
/// frame count without materializing anything.
pub fn record_payload_len(name: &str, t: &Tensor, enc: RecordEnc) -> usize {
    let data_len = match (enc, &t.data) {
        (RecordEnc::F16, Data::F32(v)) => v.len() * 2,
        (RecordEnc::Int8, Data::F32(v)) => Q_PREFIX + v.len(),
        (RecordEnc::Int4, Data::F32(v)) => Q_PREFIX + v.len().div_ceil(2),
        _ => t.data.len() * 4,
    };
    4 + name.len() + 1 + 1 + 1 + 4 * t.shape.len() + 4 + data_len
}

/// Serialize one named tensor as a v2 record payload:
/// `str name | u8 dtype | u8 enc | u8 ndim | u32 dims.. | u32 len | bytes`.
pub fn encode_record(name: &str, t: &Tensor, enc: RecordEnc) -> Vec<u8> {
    let mut out = Vec::with_capacity(record_payload_len(name, t, enc));
    write_record_into(&mut out, name, t, enc);
    out
}

/// Append one record payload to an existing writer (the sender's
/// zero-extra-copy path: the length prefix and payload share one buffer).
pub fn write_record(w: &mut Writer, name: &str, t: &Tensor, enc: RecordEnc) {
    write_record_into(w.vec_mut(), name, t, enc);
}

/// Encode one record straight into a pooled buffer — the zero-copy send
/// path: the codec output lands in the frame's eventual backing store,
/// with no intermediate `Vec` per record.
pub fn encode_record_into(name: &str, t: &Tensor, enc: RecordEnc, out: &mut crate::util::pool::PoolBuf) {
    write_record_into(out.vec_mut(), name, t, enc);
}

/// The encode-into primitive behind [`encode_record`], [`write_record`]
/// and [`encode_record_into`]: appends the record bytes to `out` with the
/// quantized/f16 payload encoded in place (no per-codec temporary).
pub fn write_record_into(out: &mut Vec<u8>, name: &str, t: &Tensor, enc: RecordEnc) {
    out.reserve(record_payload_len(name, t, enc));
    out.extend_from_slice(&(name.len() as u32).to_le_bytes());
    out.extend_from_slice(name.as_bytes());
    out.push(t.dtype().tag());
    // The compressed encodings apply to f32 data only; i32 falls back to
    // raw on the wire exactly as before.
    let enc = match (enc, &t.data) {
        (RecordEnc::F16 | RecordEnc::Int8 | RecordEnc::Int4, Data::F32(_)) => enc,
        _ => RecordEnc::Raw,
    };
    out.push(enc.tag());
    out.push(t.shape.len() as u8);
    for &d in &t.shape {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    // Reserve the u32 payload-length slot, encode in place, patch it —
    // keeps the length prefix and payload in one buffer without
    // precomputing the codec's output size twice.
    let len_at = out.len();
    out.extend_from_slice(&[0u8; 4]);
    match (enc, &t.data) {
        (RecordEnc::F16, Data::F32(v)) => f32_to_f16_into(v, out),
        (RecordEnc::Int8, Data::F32(v)) => f32_to_q8_into(v, out),
        (RecordEnc::Int4, Data::F32(v)) => f32_to_q4_into(v, out),
        (_, Data::F32(v)) => out.extend_from_slice(bytes::f32_slice_as_bytes(v)),
        (_, Data::I32(v)) => out.extend_from_slice(bytes::i32_slice_as_bytes(v)),
    }
    let n = (out.len() - len_at - 4) as u32;
    out[len_at..len_at + 4].copy_from_slice(&n.to_le_bytes());
}

/// Decode one v2 record payload back into a named tensor. F16-encoded
/// payloads are expanded to f32 here — per-record dequantization on the
/// receive side.
pub fn decode_record(buf: &[u8]) -> Result<(String, Tensor), ByteError> {
    let mut r = Reader::new(buf);
    let name = r.str()?;
    let dtype = DType::from_tag(r.u8()?).ok_or(ByteError {
        offset: r.pos(),
        msg: "bad dtype tag".into(),
    })?;
    let enc = RecordEnc::from_tag(r.u8()?).ok_or(ByteError {
        offset: r.pos(),
        msg: "bad record encoding tag".into(),
    })?;
    let ndim = r.u8()? as usize;
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        shape.push(r.u32()? as usize);
    }
    let len = r.u32()? as usize;
    let raw = r.take(len)?;
    r.expect_end()?;
    let numel: usize = shape.iter().product();
    let t = match (dtype, enc) {
        (DType::F32, RecordEnc::Raw) => Tensor {
            shape,
            data: Data::F32(bytes::bytes_to_f32_vec(raw)?),
        },
        (DType::F32, RecordEnc::F16) => Tensor {
            shape,
            data: Data::F32(f16_bytes_to_f32(raw)?),
        },
        (DType::F32, RecordEnc::Int8) => Tensor {
            shape,
            data: Data::F32(q8_bytes_to_f32(raw)?),
        },
        (DType::F32, RecordEnc::Int4) => Tensor {
            shape,
            data: Data::F32(q4_bytes_to_f32(raw, numel)?),
        },
        (DType::I32, RecordEnc::Raw) => Tensor {
            shape,
            data: Data::I32(bytes::bytes_to_i32_vec(raw)?),
        },
        (DType::I32, enc) => {
            return Err(ByteError {
                offset: 0,
                msg: format!("record {name}: {} encoding on i32 tensor", enc.as_str()),
            })
        }
    };
    if t.data.len() != numel {
        return Err(ByteError {
            offset: 0,
            msg: format!("record {name}: shape/len mismatch"),
        });
    }
    Ok((name, t))
}

/// The aggregation hot loop: `a[i] += alpha * b[i]`. Kept as a free fn so
/// benches can hit it directly; written to let LLVM auto-vectorize.
#[inline]
pub fn axpy_slice(a: &mut [f32], alpha: f32, b: &[f32]) {
    let n = a.len().min(b.len());
    let (a, b) = (&mut a[..n], &b[..n]);
    for i in 0..n {
        a[i] += alpha * b[i];
    }
}

/// The streaming-aggregation hot loop: `a[i] += c * (b[i] - a[i])`, the
/// incremental weighted-mean update. Free fn for the same bench reasons
/// as [`axpy_slice`].
#[inline]
pub fn lerp_slice(a: &mut [f32], c: f32, b: &[f32]) {
    let n = a.len().min(b.len());
    let (a, b) = (&mut a[..n], &b[..n]);
    for i in 0..n {
        a[i] += c * (b[i] - a[i]);
    }
}

// ------------------------------------------------------------ int8 / int4
//
// Affine per-record quantization: the payload carries its own `f32 scale
// | f32 min` prefix, so each record dequantizes on its own — the same
// self-delimiting property the v2 record format is built on.

/// Byte length of the quantization-parameter prefix (`f32 scale | f32 min`).
pub const Q_PREFIX: usize = 8;

/// Affine quantization parameters for a slice at `levels + 1` code points:
/// `(scale, min)` with `scale = (max - min) / levels`. Degenerate inputs
/// (empty, constant, or non-finite range) get `scale = 0`, which decodes
/// every element to `min`.
fn affine_params(v: &[f32], levels: f32) -> (f32, f32) {
    // Eight independent accumulator lanes break the loop-carried min/max
    // dependency so LLVM can keep the scan in vector registers (~4x over
    // the scalar reduction on a long slice). `f32::min`/`max` ignore NaN
    // operands lane-wise exactly as the scalar loop did, so the reduction
    // is value-identical in every case, NaNs included.
    let mut lo = [f32::INFINITY; 8];
    let mut hi = [f32::NEG_INFINITY; 8];
    let mut chunks = v.chunks_exact(8);
    for c in &mut chunks {
        for i in 0..8 {
            lo[i] = lo[i].min(c[i]);
            hi[i] = hi[i].max(c[i]);
        }
    }
    let (mut lo, mut hi) = (
        lo.iter().copied().fold(f32::INFINITY, f32::min),
        hi.iter().copied().fold(f32::NEG_INFINITY, f32::max),
    );
    for &x in chunks.remainder() {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if !lo.is_finite() || !hi.is_finite() || hi <= lo {
        return (0.0, if lo.is_finite() { lo } else { 0.0 });
    }
    ((hi - lo) / levels, lo)
}

fn read_q_prefix(b: &[u8]) -> Result<(f32, f32), ByteError> {
    if b.len() < Q_PREFIX {
        return Err(ByteError {
            offset: 0,
            msg: "quantized payload shorter than its scale/min prefix".into(),
        });
    }
    let scale = f32::from_le_bytes(b[0..4].try_into().unwrap());
    let min = f32::from_le_bytes(b[4..8].try_into().unwrap());
    Ok((scale, min))
}

/// Encode an f32 slice as affine int8 bytes: `f32 scale | f32 min | one
/// code byte per element`.
pub fn f32_to_q8_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(Q_PREFIX + v.len());
    f32_to_q8_into(v, &mut out);
    out
}

/// Appending form of [`f32_to_q8_bytes`] (byte-identical output).
pub fn f32_to_q8_into(v: &[f32], out: &mut Vec<u8>) {
    let (scale, min) = affine_params(v, 255.0);
    out.reserve(Q_PREFIX + v.len());
    out.extend_from_slice(&scale.to_le_bytes());
    out.extend_from_slice(&min.to_le_bytes());
    if scale <= 0.0 {
        // degenerate range: every code is 0 — skip the per-element math
        let end = out.len() + v.len();
        out.resize(end, 0);
        return;
    }
    // The division must stay a division (not a precomputed reciprocal
    // multiply): the golden wire fixtures pin these exact code bytes.
    out.extend(
        v.iter()
            .map(|&x| ((x - min) / scale).round().clamp(0.0, 255.0) as u8),
    );
}

/// Decode affine int8 bytes back to f32.
pub fn q8_bytes_to_f32(b: &[u8]) -> Result<Vec<f32>, ByteError> {
    let (scale, min) = read_q_prefix(b)?;
    Ok(b[Q_PREFIX..].iter().map(|&q| min + q as f32 * scale).collect())
}

/// Encode an f32 slice as affine int4 bytes: `f32 scale | f32 min | two
/// codes per byte` (low nibble first; an odd tail leaves the high nibble
/// zero).
pub fn f32_to_q4_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(Q_PREFIX + v.len().div_ceil(2));
    f32_to_q4_into(v, &mut out);
    out
}

/// Appending form of [`f32_to_q4_bytes`] (byte-identical output).
pub fn f32_to_q4_into(v: &[f32], out: &mut Vec<u8>) {
    let (scale, min) = affine_params(v, 15.0);
    out.reserve(Q_PREFIX + v.len().div_ceil(2));
    out.extend_from_slice(&scale.to_le_bytes());
    out.extend_from_slice(&min.to_le_bytes());
    if scale <= 0.0 {
        let end = out.len() + v.len().div_ceil(2);
        out.resize(end, 0);
        return;
    }
    let q = |x: f32| ((x - min) / scale).round().clamp(0.0, 15.0) as u8;
    // chunks_exact lets the pair pack run branch-free; the odd tail keeps
    // its high nibble zero exactly as before.
    let mut pairs = v.chunks_exact(2);
    out.extend((&mut pairs).map(|p| q(p[0]) | (q(p[1]) << 4)));
    if let [x] = pairs.remainder() {
        out.push(q(*x));
    }
}

/// Decode affine int4 bytes back to f32. The element count comes from the
/// record's shape (`numel`), since an odd count shares its last byte with
/// a zero pad nibble.
pub fn q4_bytes_to_f32(b: &[u8], numel: usize) -> Result<Vec<f32>, ByteError> {
    let (scale, min) = read_q_prefix(b)?;
    if b.len() - Q_PREFIX != numel.div_ceil(2) {
        return Err(ByteError {
            offset: Q_PREFIX,
            msg: format!(
                "int4 payload {} bytes does not pack {} elements",
                b.len() - Q_PREFIX,
                numel
            ),
        });
    }
    // Push both nibbles unconditionally (no per-byte length check) and
    // trim the possible pad nibble once at the end; capacity covers the
    // one-element overshoot of an odd count.
    let mut out = Vec::with_capacity(numel + 1);
    for &byte in &b[Q_PREFIX..] {
        out.push(min + (byte & 0x0F) as f32 * scale);
        out.push(min + (byte >> 4) as f32 * scale);
    }
    out.truncate(numel);
    Ok(out)
}

// --------------------------------------------------------------------- f16

/// Encode an f32 slice as IEEE half-precision bytes (quantization filter's
/// transport format).
pub fn f32_to_f16_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 2);
    f32_to_f16_into(v, &mut out);
    out
}

/// Appending form of [`f32_to_f16_bytes`] (byte-identical output).
pub fn f32_to_f16_into(v: &[f32], out: &mut Vec<u8>) {
    out.reserve(v.len() * 2);
    for &x in v {
        out.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
    }
}

/// Decode IEEE half-precision bytes back to f32.
pub fn f16_bytes_to_f32(b: &[u8]) -> Result<Vec<f32>, ByteError> {
    if b.len() % 2 != 0 {
        return Err(ByteError {
            offset: 0,
            msg: "f16 payload length must be even".into(),
        });
    }
    Ok(b.chunks_exact(2)
        .map(|c| f16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap())))
        .collect())
}

fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let frac = bits & 0x007F_FFFF;
    if exp == 255 {
        // Inf/NaN
        return sign | 0x7C00 | if frac != 0 { 0x0200 } else { 0 };
    }
    let new_exp = exp - 127 + 15;
    if new_exp >= 31 {
        return sign | 0x7C00; // overflow -> Inf
    }
    if new_exp <= 0 {
        // subnormal or zero
        if new_exp < -10 {
            return sign;
        }
        let mant = frac | 0x0080_0000;
        let shift = 14 - new_exp;
        let mut half = (mant >> shift) as u16;
        // round to nearest even
        if (mant >> (shift - 1)) & 1 != 0 {
            half += 1;
        }
        return sign | half;
    }
    let mut half = sign | ((new_exp as u16) << 10) | ((frac >> 13) as u16);
    // round to nearest (ties up — fine for transport)
    if frac & 0x1000 != 0 {
        half = half.wrapping_add(1);
    }
    half
}

fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let frac = (h & 0x03FF) as u32;
    let bits = if exp == 0 {
        if frac == 0 {
            sign
        } else {
            // subnormal: normalize
            let mut e = -1i32;
            let mut f = frac;
            while f & 0x0400 == 0 {
                f <<= 1;
                e -= 1;
            }
            let f = (f & 0x03FF) << 13;
            let e = (127 - 15 + e + 1) as u32;
            sign | (e << 23) | f
        }
    } else if exp == 31 {
        sign | 0x7F80_0000 | (frac << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (frac << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn sample_dict() -> TensorDict {
        let mut d = TensorDict::new();
        d.insert("b.weight", Tensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]));
        d.insert("a.bias", Tensor::f32(vec![3], vec![-1., 0., 1.]));
        d.insert("ids", Tensor::i32(vec![2], vec![7, -9]));
        d
    }

    #[test]
    fn to_bytes_capacity_is_exact() {
        // The capacity computation must match the encoded length exactly:
        // no reallocation mid-encode, no over-reservation per tensor.
        let buf = sample_dict().to_bytes();
        assert_eq!(buf.len(), buf.capacity());
    }

    #[test]
    fn encode_into_matches_allocating_codecs() {
        let v = vec![0.0f32, 1.5, -2.25, 7.125, 0.33, -9.0, 4.0];
        for (name, t, enc) in [
            ("w", Tensor::f32(vec![7], v.clone()), RecordEnc::Raw),
            ("w", Tensor::f32(vec![7], v.clone()), RecordEnc::F16),
            ("w", Tensor::f32(vec![7], v.clone()), RecordEnc::Int8),
            ("w", Tensor::f32(vec![7], v.clone()), RecordEnc::Int4),
            ("ids", Tensor::i32(vec![2], vec![3, -4]), RecordEnc::Int8),
            ("flat", Tensor::f32(vec![0], vec![]), RecordEnc::Int4),
        ] {
            let rec = encode_record(name, &t, enc);
            assert_eq!(rec.len(), record_payload_len(name, &t, enc));
            let mut pooled = crate::util::pool::take(rec.len());
            encode_record_into(name, &t, enc, &mut pooled);
            assert_eq!(&*pooled.freeze(), &rec[..]);
        }
    }

    #[test]
    fn iteration_is_sorted_by_name() {
        let d = sample_dict();
        let names: Vec<&str> = d.names().collect();
        assert_eq!(names, vec!["a.bias", "b.weight", "ids"]);
    }

    #[test]
    fn wire_roundtrip() {
        let d = sample_dict();
        let bytes = d.to_bytes();
        let d2 = TensorDict::from_bytes(&bytes).unwrap();
        assert_eq!(d, d2);
    }

    #[test]
    fn wire_rejects_corruption() {
        let d = sample_dict();
        let mut bytes = d.to_bytes();
        bytes.truncate(bytes.len() - 3);
        assert!(TensorDict::from_bytes(&bytes).is_err());
        assert!(TensorDict::from_bytes(&[9, 9]).is_err());
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = sample_dict();
        let b = sample_dict();
        a.axpy(2.0, &b);
        assert_eq!(a.get("a.bias").unwrap().as_f32().unwrap(), &[-3., 0., 3.]);
        // i32 tensors are untouched by scale
        a.scale(0.5);
        assert_eq!(a.get("a.bias").unwrap().as_f32().unwrap(), &[-1.5, 0., 1.5]);
        assert_eq!(a.get("ids").unwrap().as_i32().unwrap(), &[7, -9]);
    }

    #[test]
    fn subset_and_schema() {
        let d = sample_dict();
        let s = d.subset(&["a.bias".to_string(), "missing".to_string()]);
        assert_eq!(s.len(), 1);
        assert!(d.same_schema(&d.clone()));
        assert!(!d.same_schema(&s));
        let mut wrong_shape = d.clone();
        wrong_shape.insert("a.bias", Tensor::zeros(vec![4]));
        assert!(!d.same_schema(&wrong_shape));
    }

    #[test]
    fn zeros_like_and_norm() {
        let d = sample_dict();
        let z = d.zeros_like();
        assert!(d.same_schema(&z));
        assert_eq!(z.l2_norm(), 0.0);
        let expected = (1.0f64 + 4. + 9. + 16. + 25. + 36. + 1. + 0. + 1.).sqrt();
        assert!((d.l2_norm() - expected).abs() < 1e-9);
    }

    #[test]
    fn f16_roundtrip_known_values() {
        for &x in &[0.0f32, 1.0, -1.0, 0.5, 65504.0, 1e-8, -2.25, 3.14159] {
            let enc = f32_to_f16_bytes(&[x]);
            let dec = f16_bytes_to_f32(&enc).unwrap()[0];
            let tol = (x.abs() * 1e-3).max(1e-7);
            assert!((dec - x).abs() <= tol, "{x} -> {dec}");
        }
        // overflow saturates to Inf
        let enc = f32_to_f16_bytes(&[1e9]);
        assert!(f16_bytes_to_f32(&enc).unwrap()[0].is_infinite());
    }

    #[test]
    fn prop_wire_roundtrip() {
        prop::check("tensordict wire roundtrip", 60, |g| {
            let mut d = TensorDict::new();
            let n_tensors = g.usize_in(0, 6);
            for i in 0..n_tensors {
                let data = g.f32s(0, 200);
                let name = format!("{}_{i}", g.ident());
                d.insert(name, Tensor::f32(vec![data.len()], data));
            }
            let d2 = TensorDict::from_bytes(&d.to_bytes()).map_err(|e| e.to_string())?;
            prop::assert_that(d == d2, "roundtrip mismatch")
        });
    }

    #[test]
    fn prop_f16_roundtrip_within_half_precision() {
        prop::check("f16 transport error bound", 100, |g| {
            let x = g.f32_in(-1000.0, 1000.0);
            let dec = f16_bytes_to_f32(&f32_to_f16_bytes(&[x])).unwrap()[0];
            // half has ~2^-11 relative precision
            prop::assert_close(dec as f64, x as f64, 2e-3, "f16")
        });
    }

    #[test]
    fn record_roundtrip_raw_and_f16() {
        let d = sample_dict();
        for (name, t) in d.iter() {
            let payload = encode_record(name, t, RecordEnc::Raw);
            assert_eq!(payload.len(), record_payload_len(name, t, RecordEnc::Raw));
            let (n2, t2) = decode_record(&payload).unwrap();
            assert_eq!((n2.as_str(), &t2), (name, t));
        }
        // f16 halves the data bytes of f32 tensors; i32 falls back to raw
        let t = Tensor::f32(vec![4], vec![1.0, -0.5, 2.25, 100.0]);
        let payload = encode_record("w", &t, RecordEnc::F16);
        assert_eq!(payload.len(), record_payload_len("w", &t, RecordEnc::F16));
        let (_, t2) = decode_record(&payload).unwrap();
        for (a, b) in t.as_f32().unwrap().iter().zip(t2.as_f32().unwrap()) {
            assert!((a - b).abs() <= a.abs() * 2e-3 + 1e-7, "{a} {b}");
        }
        let ids = Tensor::i32(vec![2], vec![3, -9]);
        let payload = encode_record("ids", &ids, RecordEnc::F16);
        let (_, back) = decode_record(&payload).unwrap();
        assert_eq!(back, ids);
    }

    #[test]
    fn record_roundtrip_int8_and_int4() {
        let t = Tensor::f32(vec![5], vec![-4.0, -1.0, 0.0, 2.5, 4.0]);
        for enc in [RecordEnc::Int8, RecordEnc::Int4] {
            let payload = encode_record("w", &t, enc);
            assert_eq!(payload.len(), record_payload_len("w", &t, enc));
            let (n2, t2) = decode_record(&payload).unwrap();
            assert_eq!(n2, "w");
            assert_eq!(t2.shape, t.shape);
            let scale = match enc {
                RecordEnc::Int8 => 8.0 / 255.0,
                _ => 8.0 / 15.0,
            };
            for (a, b) in t.as_f32().unwrap().iter().zip(t2.as_f32().unwrap()) {
                assert!((a - b).abs() <= scale * 0.5 + 1e-5, "{a} {b} ({enc:?})");
            }
        }
        // i32 tensors fall back to raw under both quantized encodings
        let ids = Tensor::i32(vec![2], vec![3, -9]);
        for enc in [RecordEnc::Int8, RecordEnc::Int4] {
            let (_, back) = decode_record(&encode_record("ids", &ids, enc)).unwrap();
            assert_eq!(back, ids);
        }
        // constant and empty tensors survive exactly (scale = 0 path)
        let flat = Tensor::f32(vec![3], vec![2.5, 2.5, 2.5]);
        let (_, back) = decode_record(&encode_record("flat", &flat, RecordEnc::Int4)).unwrap();
        assert_eq!(back, flat);
        let empty = Tensor::f32(vec![0], vec![]);
        let (_, back) = decode_record(&encode_record("e", &empty, RecordEnc::Int8)).unwrap();
        assert_eq!(back, empty);
    }

    #[test]
    fn int4_odd_length_packs_tail_nibble() {
        for n in [1usize, 3, 7] {
            let data: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let t = Tensor::f32(vec![n], data.clone());
            let payload = encode_record("w", &t, RecordEnc::Int4);
            assert_eq!(payload.len(), record_payload_len("w", &t, RecordEnc::Int4));
            let (_, t2) = decode_record(&payload).unwrap();
            assert_eq!(t2.numel(), n);
            let scale = if n > 1 { (n - 1) as f32 / 15.0 } else { 0.0 };
            for (a, b) in data.iter().zip(t2.as_f32().unwrap()) {
                assert!((a - b).abs() <= scale * 0.5 + 1e-5, "n={n} {a} {b}");
            }
        }
    }

    #[test]
    fn prop_int8_int4_error_bounded_by_half_step() {
        prop::check("int8/int4 dequantize error bound", 80, |g| {
            let data = g.f32s(1, 200);
            let lo = data.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = data.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let name = g.ident();
            for (enc, levels) in [(RecordEnc::Int8, 255.0f32), (RecordEnc::Int4, 15.0f32)] {
                let t = Tensor::f32(vec![data.len()], data.clone());
                let (n2, t2) = decode_record(&encode_record(&name, &t, enc))
                    .map_err(|e| e.to_string())?;
                prop::assert_that(n2 == name, "name mismatch")?;
                let bound = if hi > lo { (hi - lo) / levels * 0.5 } else { 0.0 };
                for (a, b) in data.iter().zip(t2.as_f32().unwrap()) {
                    prop::assert_that(
                        (a - b).abs() <= bound + bound.abs() * 1e-4 + 1e-6,
                        "dequantize error above half quantization step",
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn record_rejects_corruption() {
        let t = Tensor::f32(vec![3], vec![1., 2., 3.]);
        let payload = encode_record("w", &t, RecordEnc::Raw);
        assert!(decode_record(&payload[..payload.len() - 2]).is_err()); // truncated
        let mut bad = payload.clone();
        bad[4 + 1] = 9; // dtype tag (after name "w": u32 len + 1 byte)
        assert!(decode_record(&bad).is_err());
        let mut bad = payload.clone();
        bad[4 + 2] = 7; // encoding tag
        assert!(decode_record(&bad).is_err());
        // shape/len mismatch: claim a bigger dim
        let mut bad = payload;
        bad[4 + 4] = 9; // first dim low byte (after name, dtype, enc, ndim)
        assert!(decode_record(&bad).is_err());
        // int4: a shape that disagrees with the packed byte count is rejected
        let t = Tensor::f32(vec![4], vec![1., 2., 3., 4.]);
        let mut bad = encode_record("w", &t, RecordEnc::Int4);
        bad[4 + 4] = 9; // 2 packed bytes cannot hold 9 elements
        assert!(decode_record(&bad).is_err());
        // int8: a payload shorter than its scale/min prefix is rejected
        assert!(q8_bytes_to_f32(&[0, 0, 0]).is_err());
    }

    #[test]
    fn prop_record_roundtrip() {
        prop::check("tensor record roundtrip", 80, |g| {
            let data = g.f32s(0, 300);
            let name = g.ident();
            let t = Tensor::f32(vec![data.len()], data);
            let (n2, t2) =
                decode_record(&encode_record(&name, &t, RecordEnc::Raw)).map_err(|e| e.to_string())?;
            prop::assert_that(n2 == name && t2 == t, "record mismatch")
        });
    }

    #[test]
    fn lerp_is_running_mean_step() {
        let mut a = sample_dict();
        let b = sample_dict();
        // lerp toward an identical dict is a no-op
        a.lerp(0.5, &b);
        assert_eq!(a, b);
        // halfway toward zeros halves every f32 value, leaves i32 alone
        let z = b.zeros_like();
        a.lerp(0.5, &z);
        assert_eq!(a.get("a.bias").unwrap().as_f32().unwrap(), &[-0.5, 0., 0.5]);
        assert_eq!(a.get("ids").unwrap().as_i32().unwrap(), &[7, -9]);
    }

    #[test]
    fn prop_lerp_matches_f64_oracle() {
        prop::check("lerp vs f64 oracle", 60, |g| {
            let a0 = g.f32s(1, 300);
            let b: Vec<f32> = (0..a0.len()).map(|_| g.f32_in(-10.0, 10.0)).collect();
            let c = g.f32_in(0.0, 1.0);
            let mut a = a0.clone();
            lerp_slice(&mut a, c, &b);
            for i in 0..a.len() {
                let oracle = a0[i] as f64 + c as f64 * (b[i] as f64 - a0[i] as f64);
                prop::assert_close(a[i] as f64, oracle, 1e-5, "lerp elem")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_axpy_matches_f64_oracle() {
        prop::check("axpy vs f64 oracle", 60, |g| {
            let a0 = g.f32s(1, 300);
            let b: Vec<f32> = (0..a0.len()).map(|_| g.f32_in(-10.0, 10.0)).collect();
            let alpha = g.f32_in(-2.0, 2.0);
            let mut a = a0.clone();
            axpy_slice(&mut a, alpha, &b);
            for i in 0..a.len() {
                let oracle = a0[i] as f64 + alpha as f64 * b[i] as f64;
                prop::assert_close(a[i] as f64, oracle, 1e-5, "axpy elem")?;
            }
            Ok(())
        });
    }
}
