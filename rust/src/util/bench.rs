//! Micro-benchmark harness (criterion is not in the offline vendor set):
//! warmup + timed iterations, reporting mean / p50 / p95 and derived
//! throughput. Used by the `cargo bench` targets in `rust/benches/`.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    /// Mean throughput in items/sec given items processed per iteration.
    pub fn per_sec(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns / 1e9)
    }

    /// Mean throughput in MB/s given bytes per iteration.
    pub fn mb_per_sec(&self, bytes_per_iter: f64) -> f64 {
        self.per_sec(bytes_per_iter) / 1e6
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    stats_from(name, samples)
}

/// Like [`bench`] but each iteration may return early-exit data; iteration
/// count adapts so the total run stays under `budget`.
pub fn bench_budget(name: &str, budget: Duration, mut f: impl FnMut()) -> BenchStats {
    // calibrate with one run
    let t0 = Instant::now();
    f();
    let once = t0.elapsed();
    let iters = ((budget.as_secs_f64() / once.as_secs_f64().max(1e-9)) as usize).clamp(3, 1000);
    bench(name, 1.min(iters / 3), iters, f)
}

fn stats_from(name: &str, mut samples: Vec<f64>) -> BenchStats {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean_ns: mean,
        p50_ns: samples[n / 2],
        p95_ns: samples[(n * 95 / 100).min(n - 1)],
        min_ns: samples[0],
    }
}

/// Pretty-print a stats row (optionally with a throughput column).
pub fn report(s: &BenchStats, throughput: Option<String>) {
    let fmt = |ns: f64| -> String {
        if ns >= 1e9 {
            format!("{:.2}s", ns / 1e9)
        } else if ns >= 1e6 {
            format!("{:.2}ms", ns / 1e6)
        } else if ns >= 1e3 {
            format!("{:.2}us", ns / 1e3)
        } else {
            format!("{ns:.0}ns")
        }
    };
    println!(
        "  {:<44} {:>9} {:>9} {:>9}  x{:<5} {}",
        s.name,
        fmt(s.mean_ns),
        fmt(s.p50_ns),
        fmt(s.p95_ns),
        s.iters,
        throughput.unwrap_or_default()
    );
}

/// Write a machine-readable benchmark report to `BENCH_<name>.json` in
/// the current directory (`make bench` runs from the repo root, so the
/// perf trajectory of every bench is trackable across PRs). Returns the
/// path written.
pub fn emit_json(name: &str, payload: crate::util::json::Json) -> std::io::Result<String> {
    use crate::util::json::Json;
    // every report carries the run's final observability snapshot, so a
    // perf trend can be cross-read against the counters behind it
    // (allocations, writev batching, reactor load) from the same run
    let payload = match payload {
        Json::Obj(mut obj) => {
            obj.insert("obs".to_string(), crate::obs::global().snapshot());
            Json::Obj(obj)
        }
        other => other,
    };
    let path = format!("BENCH_{name}.json");
    std::fs::write(&path, payload.to_string())?;
    println!("\nwrote {path}");
    Ok(path)
}

/// A stats row as JSON (for [`emit_json`] payloads).
pub fn stats_json(s: &BenchStats) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::obj([
        ("name", Json::str(s.name.clone())),
        ("iters", Json::num(s.iters as f64)),
        ("mean_ns", Json::num(s.mean_ns)),
        ("p50_ns", Json::num(s.p50_ns)),
        ("p95_ns", Json::num(s.p95_ns)),
        ("min_ns", Json::num(s.min_ns)),
    ])
}

/// Section header matching [`report`] columns.
pub fn header(title: &str) {
    println!("\n== {title} ==");
    println!(
        "  {:<44} {:>9} {:>9} {:>9}  {:<6} {}",
        "case", "mean", "p50", "p95", "iters", "throughput"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_stats() {
        let s = bench("noop-ish", 2, 50, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(s.iters, 50);
        assert!(s.mean_ns > 0.0);
        assert!(s.p50_ns <= s.p95_ns);
        assert!(s.min_ns <= s.p50_ns);
    }

    #[test]
    fn throughput_math() {
        let s = BenchStats {
            name: "t".into(),
            iters: 1,
            mean_ns: 1e9, // 1 second
            p50_ns: 1e9,
            p95_ns: 1e9,
            min_ns: 1e9,
        };
        assert!((s.per_sec(10.0) - 10.0).abs() < 1e-9);
        assert!((s.mb_per_sec(5e6) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn budget_adapts_iters() {
        let s = bench_budget("b", Duration::from_millis(20), || {
            std::thread::sleep(Duration::from_millis(2));
        });
        assert!(s.iters >= 3 && s.iters <= 20, "{}", s.iters);
    }
}
