//! Little-endian byte buffer reader/writer + CRC32, the wire-format
//! substrate under [`crate::tensor`] serialization and the [`crate::sfm`]
//! frame layer.

/// Append-only little-endian writer over a `Vec<u8>`.
#[derive(Debug, Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer { buf: Vec::new() }
    }

    pub fn with_capacity(cap: usize) -> Writer {
        Writer {
            buf: Vec::with_capacity(cap),
        }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }
    /// Length-prefixed (u32) string.
    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.bytes(s.as_bytes());
    }
    /// Length-prefixed (u32) byte blob.
    pub fn blob(&mut self, b: &[u8]) {
        self.u32(b.len() as u32);
        self.bytes(b);
    }
    pub fn len(&self) -> usize {
        self.buf.len()
    }
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }
    /// Direct access to the backing vec, for encode-into call sites that
    /// append through a `Writer` facade without an intermediate copy.
    pub fn vec_mut(&mut self) -> &mut Vec<u8> {
        &mut self.buf
    }
    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }
}

/// Error for truncated or malformed binary input.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
#[error("bytes error at offset {offset}: {msg}")]
pub struct ByteError {
    pub offset: usize,
    pub msg: String,
}

/// Cursor-based little-endian reader.
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn err(&self, msg: &str) -> ByteError {
        ByteError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8], ByteError> {
        if self.pos + n > self.buf.len() {
            return Err(self.err(&format!(
                "need {n} bytes, {} remain",
                self.buf.len() - self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, ByteError> {
        Ok(self.take(1)?[0])
    }
    pub fn u16(&mut self) -> Result<u16, ByteError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    pub fn u32(&mut self) -> Result<u32, ByteError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn u64(&mut self) -> Result<u64, ByteError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn f32(&mut self) -> Result<f32, ByteError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn str(&mut self) -> Result<String, ByteError> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| self.err("invalid utf8"))
    }
    pub fn blob(&mut self) -> Result<&'a [u8], ByteError> {
        let n = self.u32()? as usize;
        self.take(n)
    }
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
    pub fn pos(&self) -> usize {
        self.pos
    }
    pub fn expect_end(&self) -> Result<(), ByteError> {
        if self.remaining() == 0 {
            Ok(())
        } else {
            Err(self.err(&format!("{} trailing bytes", self.remaining())))
        }
    }
}

/// CRC-32 (IEEE 802.3, reflected 0xEDB88320) with a lazily-built table.
/// Used as the per-frame checksum in the SFM layer.
pub fn crc32(data: &[u8]) -> u32 {
    crc32_update(0xFFFF_FFFF, data) ^ 0xFFFF_FFFF
}

/// Streaming form: feed successive chunks with `state` starting at
/// `0xFFFF_FFFF`, then XOR the final state with `0xFFFF_FFFF`.
pub fn crc32_update(mut state: u32, data: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    for &b in data {
        state = table[((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    state
}

/// Reinterpret f32 slice as bytes (little-endian hosts only, which this
/// crate targets; asserts at compile time below).
pub fn f32_slice_as_bytes(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// Copy bytes into an f32 vec (handles unaligned input).
pub fn bytes_to_f32_vec(b: &[u8]) -> Result<Vec<f32>, ByteError> {
    if b.len() % 4 != 0 {
        return Err(ByteError {
            offset: 0,
            msg: format!("byte length {} not a multiple of 4", b.len()),
        });
    }
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Same for i32.
pub fn bytes_to_i32_vec(b: &[u8]) -> Result<Vec<i32>, ByteError> {
    if b.len() % 4 != 0 {
        return Err(ByteError {
            offset: 0,
            msg: format!("byte length {} not a multiple of 4", b.len()),
        });
    }
    Ok(b.chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

pub fn i32_slice_as_bytes(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

#[cfg(target_endian = "big")]
compile_error!("fedflare wire format assumes a little-endian host");

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut w = Writer::new();
        w.u8(7);
        w.u16(300);
        w.u32(70_000);
        w.u64(1 << 40);
        w.f32(2.5);
        w.str("hello");
        w.blob(&[1, 2, 3]);
        let buf = w.into_vec();
        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 300);
        assert_eq!(r.u32().unwrap(), 70_000);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.f32().unwrap(), 2.5);
        assert_eq!(r.str().unwrap(), "hello");
        assert_eq!(r.blob().unwrap(), &[1, 2, 3]);
        r.expect_end().unwrap();
    }

    #[test]
    fn truncation_is_detected() {
        let mut w = Writer::new();
        w.u32(5);
        let buf = w.into_vec();
        let mut r = Reader::new(&buf[..2]);
        assert!(r.u32().is_err());
    }

    #[test]
    fn crc32_known_vectors() {
        // standard test vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn crc32_streaming_equals_oneshot() {
        let data = b"the quick brown fox jumps over the lazy dog";
        let mut st = 0xFFFF_FFFFu32;
        for chunk in data.chunks(7) {
            st = crc32_update(st, chunk);
        }
        assert_eq!(st ^ 0xFFFF_FFFF, crc32(data));
    }

    #[test]
    fn f32_bytes_roundtrip() {
        let v = vec![1.0f32, -2.5, 3.25e7];
        let b = f32_slice_as_bytes(&v);
        assert_eq!(bytes_to_f32_vec(b).unwrap(), v);
        assert!(bytes_to_f32_vec(&b[..5]).is_err());
    }
}
