//! Minimal declarative CLI argument parser (clap is not in the offline
//! vendor set). Supports `--flag`, `--key value`, `--key=value`, and
//! positional arguments, with generated `--help` text.

use std::collections::BTreeMap;

/// One declared option.
#[derive(Debug, Clone)]
struct Opt {
    name: &'static str,
    help: &'static str,
    takes_value: bool,
    default: Option<String>,
}

/// Declarative parser for one (sub)command.
#[derive(Debug)]
pub struct Args {
    program: String,
    about: &'static str,
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &'static str) -> Args {
        Args {
            program: program.to_string(),
            about,
            opts: Vec::new(),
            values: BTreeMap::new(),
            flags: BTreeMap::new(),
            positional: Vec::new(),
        }
    }

    /// Declare `--name <value>` with an optional default.
    pub fn opt(mut self, name: &'static str, default: Option<&str>, help: &'static str) -> Args {
        self.opts.push(Opt {
            name,
            help,
            takes_value: true,
            default: default.map(|s| s.to_string()),
        });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Args {
        self.opts.push(Opt {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    /// Parse a raw token list (without the program name). Returns an error
    /// string suitable for printing; `--help` returns `Err` carrying the
    /// usage text with an `"HELP"` marker prefix.
    pub fn parse(mut self, tokens: &[String]) -> Result<Parsed, String> {
        let mut i = 0;
        while i < tokens.len() {
            let tok = &tokens[i];
            if tok == "--help" || tok == "-h" {
                return Err(format!("HELP\n{}", self.usage()));
            }
            if let Some(rest) = tok.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n{}", self.usage()))?
                    .clone();
                if opt.takes_value {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            tokens
                                .get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} requires a value"))?
                        }
                    };
                    self.values.insert(name, value);
                } else {
                    if inline.is_some() {
                        return Err(format!("--{name} does not take a value"));
                    }
                    self.flags.insert(name, true);
                }
            } else {
                self.positional.push(tok.clone());
            }
            i += 1;
        }
        // apply defaults
        for opt in &self.opts {
            if opt.takes_value && !self.values.contains_key(opt.name) {
                if let Some(d) = &opt.default {
                    self.values.insert(opt.name.to_string(), d.clone());
                }
            }
        }
        Ok(Parsed {
            values: self.values,
            flags: self.flags,
            positional: self.positional,
        })
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.program, self.about);
        for opt in &self.opts {
            let left = if opt.takes_value {
                format!("  --{} <v>", opt.name)
            } else {
                format!("  --{}", opt.name)
            };
            let default = opt
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("{left:<26}{}{default}\n", opt.help));
        }
        s
    }
}

/// Parsed argument values with typed accessors.
#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }
    pub fn req(&self, name: &str) -> Result<&str, String> {
        self.get(name).ok_or_else(|| format!("--{name} is required"))
    }
    pub fn get_usize(&self, name: &str) -> Result<usize, String> {
        self.req(name)?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }
    pub fn get_u64(&self, name: &str) -> Result<u64, String> {
        self.req(name)?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }
    pub fn get_f64(&self, name: &str) -> Result<f64, String> {
        self.req(name)?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }
    pub fn has(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|t| t.to_string()).collect()
    }

    fn build() -> Args {
        Args::new("test", "a test command")
            .opt("rounds", Some("3"), "number of rounds")
            .opt("name", None, "a name")
            .flag("verbose", "chatty output")
    }

    #[test]
    fn parses_values_flags_positional() {
        let p = build()
            .parse(&toks(&["--rounds", "5", "--verbose", "pos1", "--name=x"]))
            .unwrap();
        assert_eq!(p.get_usize("rounds").unwrap(), 5);
        assert_eq!(p.get("name"), Some("x"));
        assert!(p.has("verbose"));
        assert_eq!(p.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let p = build().parse(&toks(&[])).unwrap();
        assert_eq!(p.get_usize("rounds").unwrap(), 3);
        assert_eq!(p.get("name"), None);
        assert!(!p.has("verbose"));
    }

    #[test]
    fn unknown_and_missing_value_error() {
        assert!(build().parse(&toks(&["--nope"])).is_err());
        assert!(build().parse(&toks(&["--name"])).is_err());
        assert!(build().parse(&toks(&["--verbose=1"])).is_err());
    }

    #[test]
    fn help_contains_options() {
        let err = build().parse(&toks(&["--help"])).unwrap_err();
        assert!(err.starts_with("HELP"));
        assert!(err.contains("--rounds"));
    }
}
