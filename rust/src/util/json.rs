//! Minimal JSON value, parser, and serializer.
//!
//! serde is unavailable in the offline vendor set, so configs, artifact
//! manifests, and metric events go through this hand-rolled implementation.
//! It supports the full JSON grammar (RFC 8259) minus exotic number forms
//! beyond f64, which is all the repo needs.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0).map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `Json::Null` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Shorthand constructors.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
    pub fn arr(items: impl IntoIterator<Item = Json>) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.is_finite() && n == n.trunc() && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{n}"));
    } else {
        // JSON has no Inf/NaN; emit null like most tolerant encoders.
        out.push_str("null");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse failure with byte position context.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
#[error("json error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            cp
                        };
                        s.push(
                            char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c).ok_or_else(|| self.err("bad utf8"))?;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("bad utf8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
                None => return Err(self.err("unterminated string")),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::str("hi"));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "d"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("d"));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ é 😀");
        // non-ascii passthrough
        let v = Json::parse("\"héllo wörld\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"x":true,"y":null},"s":"a\"b"}"#;
        let v = Json::parse(src).unwrap();
        let printed = v.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn integers_print_without_decimal() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn get_on_missing_returns_null() {
        let v = Json::parse(r#"{"a":1}"#).unwrap();
        assert!(v.get("nope").is_null());
        assert!(Json::Num(1.0).get("x").is_null());
    }
}
