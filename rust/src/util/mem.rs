//! Memory accounting for the Fig-5 experiment: a global tracked-buffer
//! counter (incremented by the streaming layer's payload allocations) plus
//! a `/proc/self/status` RSS reader, and a background sampler thread that
//! writes a time series.
//!
//! Since the observability plane landed, every process-global counter
//! here is a thin shim over the [`crate::obs`] metrics registry — the
//! same numbers appear in registry snapshots, `fedflare status`, and the
//! exporter's JSONL under the `mem.*` / `pool.*` / `sfm.*` names — while
//! this module keeps its historical function-per-counter API so hot-path
//! call sites and tests are untouched. Handles are interned once per
//! process; after that each call is a single relaxed atomic op, exactly
//! as before.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, OnceLock};
use std::time::{Duration, Instant};

use crate::obs;

/// Cache one `&'static` registry handle per metric (the registry lookup
/// takes a lock; the shims must stay lock-free after first use).
macro_rules! handle {
    ($fn_name:ident, $ty:ty, $lookup:ident, $metric:expr) => {
        fn $fn_name() -> &'static $ty {
            static H: OnceLock<&'static $ty> = OnceLock::new();
            H.get_or_init(|| obs::$lookup($metric))
        }
    };
}

// Bytes currently held by tracked streaming buffers (global).
handle!(tracked, obs::Gauge, gauge, "mem.tracked_bytes");
// Bytes of client results currently held by the server's gather path
// (the streaming aggregator's in-flight inputs) — separate from
// `mem.tracked_bytes` so a single-process simulation can still observe
// the server-side aggregation footprint in isolation.
handle!(gather, obs::Gauge, gauge, "mem.gather_bytes");
// Bytes staged by tensor-granular record assembly: out-of-order chunks
// plus the partial record at the contiguous frontier. With wire format
// v2 this is the receive-side footprint *between* frames arriving and a
// tensor record completing — O(largest tensor + in-flight chunks), where
// the v1 blob path staged the whole payload.
handle!(stage, obs::Gauge, gauge, "mem.stage_bytes");
// Cumulative bytes *discarded* by eviction: stale reassembly partials of
// vanished peers, frames of closed/aborted jobs dropped by the session
// mux. Monotonic — a serving system's "memory reclaimed from dead
// streams" gauge, so an aborted job's drained buffers are observable.
handle!(evicted, obs::Counter, counter, "mem.evicted_bytes");
// Bytes currently *parked* by receive-side throttling: frames the
// reactor has accepted but a connection's token bucket has not admitted
// downstream yet (the mux's per-connection backlog, globally summed).
handle!(parked, obs::Gauge, gauge, "mem.parked_bytes");
// Cumulative ns connections spent with a non-empty parked backlog —
// the fleet-wide "bucket throttle time" gauge.
handle!(throttle_ns, obs::Counter, counter, "mem.throttle_wait_ns");
// Buffer-pool checkouts served from a free list (no heap traffic).
handle!(pool_hits_c, obs::Counter, counter, "pool.hits");
// Buffer-pool checkouts that had to allocate (cold class or oversize).
// At steady state this must stop moving — pinned by the zero-allocation
// regression test.
handle!(pool_misses_c, obs::Counter, counter, "pool.misses");
// Bytes currently parked in the pool's free lists.
handle!(pool_held, obs::Gauge, gauge, "pool.held_bytes");
// Cumulative heap allocations that became frame payloads: pool misses
// plus unpooled `Vec<u8>` payload wraps. The per-frame allocation count
// of the data plane — zero growth per frame at steady state.
handle!(frame_allocs_c, obs::Counter, counter, "sfm.frame_allocs");
// Cumulative payload bytes memcpy'd on the send/receive path (encode
// staging, record-boundary chunk assembly, wire decode, reassembly
// concatenation). Shared-slice payload routing does not count — that is
// the point of it.
handle!(bytes_copied_c, obs::Counter, counter, "sfm.bytes_copied");
// Vectored-write syscalls issued by the TCP send path, and the frames
// they carried (frames/calls = mean batch size).
handle!(writev_calls_c, obs::Counter, counter, "sfm.writev_calls");
handle!(writev_frames_c, obs::Counter, counter, "sfm.writev_frames");

/// Record an allocation of `n` bytes in the streaming layer.
pub fn track_alloc(n: usize) {
    tracked().add(n as u64);
}

/// Record a release of `n` bytes.
pub fn track_free(n: usize) {
    tracked().sub(n as u64);
}

/// Current tracked bytes.
pub fn tracked_bytes() -> i64 {
    tracked().get()
}

/// High-water mark since process start (or [`reset_peak`]).
pub fn tracked_peak() -> u64 {
    tracked().peak()
}

pub fn reset_peak() {
    tracked().reset_peak();
}

/// Record `n` bytes entering the server-side gather path.
pub fn gather_track_alloc(n: usize) {
    gather().add(n as u64);
}

/// Record `n` bytes leaving the gather path (folded into the accumulator
/// and dropped).
pub fn gather_track_free(n: usize) {
    gather().sub(n as u64);
}

/// Bytes of in-flight gathered results right now.
pub fn gather_bytes() -> i64 {
    gather().get()
}

/// High-water mark of the gather counter since start (or
/// [`reset_gather_peak`]).
pub fn gather_peak() -> u64 {
    gather().peak()
}

pub fn reset_gather_peak() {
    gather().reset_peak();
}

/// Record `n` bytes entering record-assembly staging.
pub fn stage_track_alloc(n: usize) {
    stage().add(n as u64);
}

/// Record `n` bytes leaving record-assembly staging (record completed or
/// assembler dropped).
pub fn stage_track_free(n: usize) {
    stage().sub(n as u64);
}

/// Bytes currently staged by record assemblers.
pub fn stage_bytes() -> i64 {
    stage().get()
}

/// High-water mark of the staging counter since start (or
/// [`reset_stage_peak`]).
pub fn stage_peak() -> u64 {
    stage().peak()
}

pub fn reset_stage_peak() {
    stage().reset_peak();
}

/// Record `n` bytes discarded by eviction (stale partial streams, frames
/// of closed jobs). Cumulative; never decremented.
pub fn track_evicted(n: usize) {
    evicted().add(n as u64);
}

/// Total bytes discarded by eviction since process start.
pub fn evicted_bytes() -> u64 {
    evicted().get()
}

/// Record `n` bytes parked by a receive-side throttle backlog (frames
/// the reactor accepted but a token bucket has not admitted yet).
pub fn park_track_alloc(n: usize) {
    parked().add(n as u64);
}

/// Record `n` parked bytes released (admitted downstream or dropped with
/// their connection).
pub fn park_track_free(n: usize) {
    parked().sub(n as u64);
}

/// Bytes currently parked across all throttled connections.
pub fn parked_bytes() -> i64 {
    parked().get()
}

/// High-water mark of the parked counter since start.
pub fn parked_peak() -> u64 {
    parked().peak()
}

/// Record `ns` nanoseconds a connection's receive path spent throttled
/// (non-empty parked backlog). Cumulative across all connections.
pub fn track_throttle_wait_ns(ns: u64) {
    throttle_ns().add(ns);
}

/// Total receive-throttle stall time, in ns, since process start.
pub fn throttle_wait_ns() -> u64 {
    throttle_ns().get()
}

/// Record a buffer-pool checkout served without allocating.
pub fn pool_hit() {
    pool_hits_c().inc();
}

/// Record a buffer-pool checkout that allocated.
pub fn pool_miss() {
    pool_misses_c().inc();
}

/// Pool checkouts served from a free list since process start.
pub fn pool_hits() -> u64 {
    pool_hits_c().get()
}

/// Pool checkouts that allocated since process start.
pub fn pool_misses() -> u64 {
    pool_misses_c().get()
}

/// Record `n` bytes entering the pool's free lists.
pub fn pool_held_add(n: usize) {
    pool_held().add(n as u64);
}

/// Record `n` bytes checked back out of the free lists.
pub fn pool_held_sub(n: usize) {
    pool_held().sub(n as u64);
}

/// Bytes currently parked in pool free lists.
pub fn pool_held_bytes() -> i64 {
    pool_held().get()
}

/// High-water mark of pooled free-list bytes since process start.
pub fn pool_held_peak() -> u64 {
    pool_held().peak()
}

/// Record one heap allocation that became a frame payload.
pub fn track_frame_alloc() {
    frame_allocs_c().inc();
}

/// Heap allocations that became frame payloads since process start
/// (cumulative; flat at steady state).
pub fn frame_allocs() -> u64 {
    frame_allocs_c().get()
}

/// Record `n` payload bytes memcpy'd on the send/receive path.
pub fn track_bytes_copied(n: usize) {
    bytes_copied_c().add(n as u64);
}

/// Payload bytes memcpy'd on the data plane since process start.
pub fn bytes_copied() -> u64 {
    bytes_copied_c().get()
}

/// Record one vectored-write syscall that carried `frames` frames.
pub fn track_writev(frames: usize) {
    writev_calls_c().inc();
    writev_frames_c().add(frames as u64);
}

/// Vectored-write syscalls issued since process start.
pub fn writev_calls() -> u64 {
    writev_calls_c().get()
}

/// Frames carried by vectored writes since process start.
pub fn writev_frames() -> u64 {
    writev_frames_c().get()
}

/// A scoped byte counter (current + high-water mark). The process-global
/// gather/stage counters above aggregate *every* node in a single-process
/// simulation; a `Counter` gives one node — e.g. the root of a
/// hierarchical topology — its own accounting, so per-node peaks are
/// observable (each `Communicator` owns one).
#[derive(Debug, Default)]
pub struct Counter {
    cur: AtomicI64,
    peak: AtomicU64,
}

impl Counter {
    pub fn new() -> Counter {
        Counter::default()
    }

    pub fn alloc(&self, n: usize) {
        let cur = self.cur.fetch_add(n as i64, Ordering::Relaxed) + n as i64;
        self.peak.fetch_max(cur.max(0) as u64, Ordering::Relaxed);
    }

    pub fn free(&self, n: usize) {
        self.cur.fetch_sub(n as i64, Ordering::Relaxed);
    }

    /// Bytes currently counted.
    pub fn bytes(&self) -> i64 {
        self.cur.load(Ordering::Relaxed)
    }

    /// High-water mark since creation (or [`Counter::reset_peak`]).
    pub fn peak(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }

    pub fn reset_peak(&self) {
        self.peak
            .store(self.bytes().max(0) as u64, Ordering::Relaxed);
    }
}

/// RAII guard counting `n` bytes against the gather counter for its
/// lifetime: the Communicator creates one per result it hands to the
/// aggregation fold, so `gather_peak()` measures how many client updates
/// the server actually held at once. [`GatherGuard::scoped`] additionally
/// counts against one node's own [`Counter`].
#[derive(Debug)]
pub struct GatherGuard {
    n: usize,
    local: Option<Arc<Counter>>,
}

impl GatherGuard {
    pub fn new(n: usize) -> GatherGuard {
        gather_track_alloc(n);
        GatherGuard { n, local: None }
    }

    /// Count against the global gather counter *and* `counter`.
    pub fn scoped(counter: &Arc<Counter>, n: usize) -> GatherGuard {
        gather_track_alloc(n);
        counter.alloc(n);
        GatherGuard {
            n,
            local: Some(counter.clone()),
        }
    }
}

impl Drop for GatherGuard {
    fn drop(&mut self) {
        gather_track_free(self.n);
        if let Some(c) = &self.local {
            c.free(self.n);
        }
    }
}

/// RAII guard that tracks a buffer's size for its lifetime.
#[derive(Debug)]
pub struct TrackedBuf {
    data: Vec<u8>,
}

impl TrackedBuf {
    pub fn new(data: Vec<u8>) -> TrackedBuf {
        track_alloc(data.len());
        TrackedBuf { data }
    }
    pub fn as_slice(&self) -> &[u8] {
        &self.data
    }
    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    /// Release tracking and return the inner buffer.
    pub fn into_vec(mut self) -> Vec<u8> {
        track_free(self.data.len());
        std::mem::take(&mut self.data)
    }
}

impl Drop for TrackedBuf {
    fn drop(&mut self) {
        track_free(self.data.len());
    }
}

/// Resident set size of this process in bytes (Linux `/proc/self/status`,
/// `VmRSS`). Returns 0 if unavailable.
pub fn rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches(" kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

/// One memory sample.
#[derive(Debug, Clone)]
pub struct MemSample {
    pub t_ms: u64,
    pub tracked: i64,
    pub rss: u64,
    /// Server-side gather bytes (in-flight aggregation inputs).
    pub gather: i64,
    /// Record-assembly staging bytes (tensor-granular receive path).
    pub stage: i64,
    pub label: String,
}

/// Background sampler: records tracked + RSS every `period` until stopped.
pub struct MemSampler {
    stop_tx: mpsc::Sender<()>,
    handle: std::thread::JoinHandle<Vec<MemSample>>,
}

impl MemSampler {
    pub fn start(period: Duration, label: &str) -> MemSampler {
        let (stop_tx, stop_rx) = mpsc::channel();
        let label = label.to_string();
        let handle = std::thread::spawn(move || {
            let t0 = Instant::now();
            let mut samples = Vec::new();
            loop {
                samples.push(MemSample {
                    t_ms: t0.elapsed().as_millis() as u64,
                    tracked: tracked_bytes(),
                    rss: rss_bytes(),
                    gather: gather_bytes(),
                    stage: stage_bytes(),
                    label: label.clone(),
                });
                match stop_rx.recv_timeout(period) {
                    Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    Err(mpsc::RecvTimeoutError::Timeout) => continue,
                }
            }
            samples
        });
        MemSampler { stop_tx, handle }
    }

    /// Stop and collect the series.
    pub fn stop(self) -> Vec<MemSample> {
        let _ = self.stop_tx.send(());
        self.handle.join().unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracked_buf_balances() {
        let before = tracked_bytes();
        {
            let _b = TrackedBuf::new(vec![0u8; 4096]);
            assert!(tracked_bytes() >= before + 4096);
        }
        assert_eq!(tracked_bytes(), before);
    }

    #[test]
    fn into_vec_releases_tracking() {
        let before = tracked_bytes();
        let b = TrackedBuf::new(vec![1u8; 128]);
        let v = b.into_vec();
        assert_eq!(v.len(), 128);
        assert_eq!(tracked_bytes(), before);
    }

    #[test]
    fn peak_moves_up() {
        reset_peak();
        let base = tracked_peak();
        let _b = TrackedBuf::new(vec![0u8; 1 << 16]);
        assert!(tracked_peak() >= base);
    }

    #[test]
    fn gather_guard_counts_while_alive() {
        // other tests in this binary may run gathers concurrently, so only
        // assert lower bounds / monotonic effects of our own guard
        let big = 1usize << 22; // far larger than any sibling test's payloads
        {
            let _g = GatherGuard::new(big);
            assert!(gather_bytes() >= big as i64);
            assert!(gather_peak() >= big as u64);
        }
        assert!(gather_bytes() < big as i64);
    }

    #[test]
    fn stage_counter_balances_and_peaks() {
        let big = 1usize << 23; // dwarf sibling tests' staging
        let before = stage_bytes();
        stage_track_alloc(big);
        assert!(stage_bytes() >= before + big as i64);
        assert!(stage_peak() >= big as u64);
        stage_track_free(big);
        assert!(stage_bytes() < before + big as i64);
    }

    #[test]
    fn scoped_counter_tracks_local_and_global() {
        let c = Arc::new(Counter::new());
        {
            let _g = GatherGuard::scoped(&c, 4096);
            assert_eq!(c.bytes(), 4096);
            assert!(c.peak() >= 4096);
            assert!(gather_bytes() >= 4096);
        }
        assert_eq!(c.bytes(), 0);
        assert!(c.peak() >= 4096, "peak survives the guard");
        c.reset_peak();
        assert_eq!(c.peak(), 0);
    }

    #[test]
    fn evicted_counter_is_cumulative() {
        let before = evicted_bytes();
        track_evicted(1000);
        track_evicted(24);
        assert!(evicted_bytes() >= before + 1024);
    }

    #[test]
    fn rss_reads_something_on_linux() {
        let rss = rss_bytes();
        assert!(rss > 1024 * 1024, "rss={rss}");
    }

    #[test]
    fn sampler_collects() {
        let s = MemSampler::start(Duration::from_millis(5), "test");
        std::thread::sleep(Duration::from_millis(30));
        let samples = s.stop();
        assert!(samples.len() >= 3);
        assert!(samples.iter().all(|s| s.label == "test"));
    }
}
