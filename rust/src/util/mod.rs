//! Substrate utilities built from scratch (no external crates available
//! offline beyond the `xla` closure): JSON, PRNG + distributions, byte
//! buffers + CRC32, CLI parsing, memory accounting, and a mini
//! property-testing framework.

pub mod bench;
pub mod bytes;
pub mod cli;
pub mod json;
pub mod mem;
pub mod pool;
pub mod prop;
pub mod rng;
