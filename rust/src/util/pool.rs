//! Pooled byte buffers and shared-slice payloads — the allocator of the
//! zero-copy data plane.
//!
//! [`take`] checks a buffer out of a global pool of power-of-two size
//! classes (4 KiB..4 MiB, striped free lists so reactor shards don't
//! contend on one lock). The returned [`PoolBuf`] is an owned, writable
//! `Vec<u8>` that goes back to its class's free list on drop, so at
//! steady state the send/receive hot path allocates nothing: every frame
//! payload of a size seen before is a pool hit ([`crate::util::mem`]
//! counts hits, misses, and the held-bytes high-water mark).
//!
//! [`PoolBuf::freeze`] converts the buffer into a [`Payload`] — a
//! cheap-clone shared view (`Arc`-backed offset/len slice) that the frame
//! layer routes through mux demux, priority-lane parking, throttle
//! backlogs, and reassembly **without copying**: cloning a frame clones a
//! pointer, and [`Payload::slice`] cuts a sub-view of the same backing
//! buffer (how [`crate::message::FrameIter`] carves chunk-sized frames
//! out of one encoded record). When the last view drops, the backing
//! buffer returns to the pool.

use std::ops::{Deref, Range};
use std::sync::{Arc, Mutex, OnceLock};

use super::mem;

/// Smallest pooled size class: 4 KiB.
const MIN_CLASS_SHIFT: u32 = 12;
/// Largest pooled size class: 4 MiB. Bigger requests are unpooled (and
/// counted as misses) — at the default 1 MB chunk size nothing on the
/// frame path should ever exceed this.
const MAX_CLASS_SHIFT: u32 = 22;
const CLASSES: usize = (MAX_CLASS_SHIFT - MIN_CLASS_SHIFT + 1) as usize;
/// Free-list stripes per class: checkouts/returns from different threads
/// (reactor shards, per-job controller threads) spread over independent
/// locks.
const STRIPES: usize = 8;
/// Buffers retained per stripe per class; overflow frees to the global
/// allocator so an eviction burst cannot grow the pool without bound.
const STRIPE_CAP: usize = 16;

struct Pool {
    /// `classes[c][s]` = free list of stripe `s` in size class `c`.
    classes: Vec<[Mutex<Vec<Vec<u8>>>; STRIPES]>,
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        classes: (0..CLASSES)
            .map(|_| std::array::from_fn(|_| Mutex::new(Vec::new())))
            .collect(),
    })
}

/// Size class index for a capacity request, or `None` if it exceeds the
/// largest class. Class `c` holds buffers of exactly
/// `1 << (MIN_CLASS_SHIFT + c)` bytes of capacity.
fn class_of(min_cap: usize) -> Option<usize> {
    let shift = usize::BITS - min_cap.max(1).saturating_sub(1).leading_zeros();
    let shift = shift.max(MIN_CLASS_SHIFT);
    if shift > MAX_CLASS_SHIFT {
        None
    } else {
        Some((shift - MIN_CLASS_SHIFT) as usize)
    }
}

fn class_bytes(class: usize) -> usize {
    1usize << (MIN_CLASS_SHIFT + class as u32)
}

/// The stripe this thread prefers (round-robin assigned at first use, so
/// a pool of worker threads spreads evenly without hashing thread ids).
fn home_stripe() -> usize {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static STRIPE: usize = NEXT.fetch_add(1, Ordering::Relaxed) % STRIPES;
    }
    STRIPE.with(|s| *s)
}

/// Check a buffer of at least `min_cap` capacity out of the pool. A hit
/// reuses a previously returned buffer of the same size class (no heap
/// traffic); a miss allocates one at full class capacity so it is
/// poolable on return. Requests beyond the largest class get an unpooled
/// buffer (counted as a miss).
pub fn take(min_cap: usize) -> PoolBuf {
    let Some(class) = class_of(min_cap) else {
        mem::pool_miss();
        mem::track_frame_alloc();
        return PoolBuf {
            buf: Vec::with_capacity(min_cap),
            class: None,
        };
    };
    let p = pool();
    let home = home_stripe();
    for i in 0..STRIPES {
        let stripe = &p.classes[class][(home + i) % STRIPES];
        if let Some(buf) = stripe.lock().expect("pool stripe poisoned").pop() {
            mem::pool_hit();
            mem::pool_held_sub(buf.capacity());
            return PoolBuf {
                buf,
                class: Some(class),
            };
        }
    }
    mem::pool_miss();
    mem::track_frame_alloc();
    PoolBuf {
        buf: Vec::with_capacity(class_bytes(class)),
        class: Some(class),
    }
}

/// Return a buffer to its class's free list (or free it if the stripe is
/// full / the buffer is unpooled).
fn give_back(mut buf: Vec<u8>, class: Option<usize>) {
    let Some(class) = class else {
        return;
    };
    if buf.capacity() < class_bytes(class) {
        // shrank under us (e.g. a caller took the Vec out) — don't pool a
        // buffer that would miss its class's capacity contract
        return;
    }
    buf.clear();
    let stripe = &pool().classes[class][home_stripe()];
    let mut list = stripe.lock().expect("pool stripe poisoned");
    if list.len() < STRIPE_CAP {
        mem::pool_held_add(buf.capacity());
        list.push(buf);
    }
}

/// An owned, writable pooled buffer (RAII: returns to the pool on drop).
/// Write through [`PoolBuf::vec_mut`], then [`PoolBuf::freeze`] into a
/// shareable [`Payload`].
#[derive(Debug, Default)]
pub struct PoolBuf {
    buf: Vec<u8>,
    class: Option<usize>,
}

impl PoolBuf {
    /// The underlying `Vec` for encoding into. Appending beyond the size
    /// class's capacity works (the Vec grows) but forfeits pooling on
    /// return, so size requests honestly via [`take`].
    pub fn vec_mut(&mut self) -> &mut Vec<u8> {
        &mut self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.buf.capacity()
    }

    /// Convert into a cheap-clone shared view. The backing buffer returns
    /// to the pool when the last [`Payload`] referencing it drops.
    pub fn freeze(mut self) -> Payload {
        let buf = std::mem::take(&mut self.buf);
        let class = self.class.take();
        let len = buf.len();
        Payload {
            chunk: Arc::new(Chunk { buf, class }),
            off: 0,
            len,
        }
    }
}

impl Deref for PoolBuf {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        &self.buf
    }
}

impl Drop for PoolBuf {
    fn drop(&mut self) {
        give_back(std::mem::take(&mut self.buf), self.class.take());
    }
}

/// Frozen backing storage of one or more [`Payload`] views.
#[derive(Debug)]
struct Chunk {
    buf: Vec<u8>,
    class: Option<usize>,
}

impl Drop for Chunk {
    fn drop(&mut self) {
        give_back(std::mem::take(&mut self.buf), self.class.take());
    }
}

fn empty_chunk() -> Arc<Chunk> {
    static EMPTY: OnceLock<Arc<Chunk>> = OnceLock::new();
    EMPTY
        .get_or_init(|| {
            Arc::new(Chunk {
                buf: Vec::new(),
                class: None,
            })
        })
        .clone()
}

/// A cheap-clone shared byte slice — the frame payload type. Dereferences
/// to `&[u8]`; `clone` copies a pointer; [`Payload::slice`] cuts a
/// sub-view of the same backing buffer. Backed either by a pooled buffer
/// (via [`PoolBuf::freeze`] — returns to the pool when the last view
/// drops) or by a plain `Vec<u8>` (via `From`, for control frames and
/// tests).
#[derive(Clone)]
pub struct Payload {
    chunk: Arc<Chunk>,
    off: usize,
    len: usize,
}

impl Payload {
    /// The empty payload. Allocation-free: every empty payload shares one
    /// static backing chunk (heartbeats and FINs are sent per tick fleet-
    /// wide; they must not cost an allocation each).
    pub fn new() -> Payload {
        Payload {
            chunk: empty_chunk(),
            off: 0,
            len: 0,
        }
    }

    /// A zero-copy sub-view sharing this payload's backing buffer.
    pub fn slice(&self, range: Range<usize>) -> Payload {
        assert!(
            range.start <= range.end && range.end <= self.len,
            "slice {range:?} out of bounds for payload of {}",
            self.len
        );
        Payload {
            chunk: self.chunk.clone(),
            off: self.off + range.start,
            len: range.end - range.start,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.chunk.buf[self.off..self.off + self.len]
    }
}

impl Default for Payload {
    fn default() -> Payload {
        Payload::new()
    }
}

impl Deref for Payload {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl AsRef<[u8]> for Payload {
    fn as_ref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl From<Vec<u8>> for Payload {
    /// Wrap an existing heap buffer (unpooled). This is the control-frame
    /// and test path; data frames should come from [`take`] +
    /// [`PoolBuf::freeze`]. Counted in [`mem::frame_allocs`] so the
    /// steady-state zero-allocation regression test sees strays.
    fn from(buf: Vec<u8>) -> Payload {
        mem::track_frame_alloc();
        let len = buf.len();
        Payload {
            chunk: Arc::new(Chunk { buf, class: None }),
            off: 0,
            len,
        }
    }
}

impl From<&[u8]> for Payload {
    fn from(b: &[u8]) -> Payload {
        b.to_vec().into()
    }
}

impl std::fmt::Debug for Payload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?}", self.as_slice())
    }
}

impl PartialEq for Payload {
    fn eq(&self, other: &Payload) -> bool {
        self.as_slice() == other.as_slice()
    }
}
impl Eq for Payload {}

impl PartialEq<[u8]> for Payload {
    fn eq(&self, other: &[u8]) -> bool {
        self.as_slice() == other
    }
}
impl PartialEq<&[u8]> for Payload {
    fn eq(&self, other: &&[u8]) -> bool {
        self.as_slice() == *other
    }
}
impl PartialEq<Vec<u8>> for Payload {
    fn eq(&self, other: &Vec<u8>) -> bool {
        self.as_slice() == other.as_slice()
    }
}
impl PartialEq<Payload> for Vec<u8> {
    fn eq(&self, other: &Payload) -> bool {
        self.as_slice() == other.as_slice()
    }
}
impl<const N: usize> PartialEq<[u8; N]> for Payload {
    fn eq(&self, other: &[u8; N]) -> bool {
        self.as_slice() == other
    }
}
impl<const N: usize> PartialEq<&[u8; N]> for Payload {
    fn eq(&self, other: &&[u8; N]) -> bool {
        self.as_slice() == *other
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_classes_round_up_and_cap_out() {
        assert_eq!(class_of(0), Some(0));
        assert_eq!(class_of(1), Some(0));
        assert_eq!(class_of(4096), Some(0));
        assert_eq!(class_of(4097), Some(1));
        assert_eq!(class_of(8192), Some(1));
        assert_eq!(class_of(1 << 22), Some(CLASSES - 1));
        assert_eq!(class_of((1 << 22) + 1), None);
        for c in 0..CLASSES {
            assert!(class_bytes(c) >= 4096);
        }
    }

    #[test]
    fn checkout_return_is_a_hit_and_promotion_changes_class() {
        // round 1: miss, allocate; return to pool on drop
        let hits0 = mem::pool_hits();
        {
            let mut b = take(100);
            b.vec_mut().extend_from_slice(&[1, 2, 3]);
            assert_eq!(&b[..], &[1, 2, 3]);
            assert!(b.capacity() >= 4096);
        }
        // round 2: same class — must be a hit, and arrive cleared
        let b = take(4000);
        assert!(mem::pool_hits() > hits0, "second checkout should hit");
        assert!(b.is_empty());
        drop(b);

        // size-class promotion: a request one byte over the class boundary
        // gets the next class up, not a truncated buffer
        let small = take(4096);
        let promoted = take(4097);
        assert!(promoted.capacity() >= 8192);
        assert!(promoted.capacity() > small.capacity());

        // oversize requests are honored unpooled
        let big = take((1 << 22) + 5);
        assert!(big.capacity() >= (1 << 22) + 5);
    }

    #[test]
    fn freeze_share_slice_and_return() {
        let mut b = take(64);
        b.vec_mut().extend_from_slice(b"hello, pooled world");
        let p = b.freeze();
        let view = p.slice(7..13);
        assert_eq!(view, b"pooled");
        let clone = p.clone();
        drop(p);
        // backing buffer still alive through the clone and the sub-view
        assert_eq!(clone, b"hello, pooled world");
        assert_eq!(view, b"pooled");
        let hits0 = mem::pool_hits();
        drop(clone);
        drop(view);
        // last view gone -> buffer is back in the pool -> next take hits
        let again = take(64);
        assert!(mem::pool_hits() > hits0, "frozen buffer should return");
        drop(again);
    }

    #[test]
    fn empty_payload_is_allocation_free_and_comparable() {
        let a0 = mem::frame_allocs();
        let e = Payload::new();
        let e2 = Payload::default();
        assert_eq!(mem::frame_allocs(), a0, "empty payloads must not allocate");
        assert!(e.is_empty());
        assert_eq!(e, e2);
        assert_eq!(e, Vec::<u8>::new());
        assert_eq!(e.slice(0..0), e2);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn slice_out_of_bounds_panics() {
        let p: Payload = vec![1u8, 2, 3].into();
        let _ = p.slice(1..5);
    }

    #[test]
    fn concurrent_checkout_return_across_threads() {
        // satellite: pool correctness under the reactor-shard access
        // pattern — many threads checking out, writing, freezing, and
        // dropping concurrently. Asserts no deadlock/panic, data
        // integrity, and that the held-bytes gauge stays non-negative.
        let threads: Vec<_> = (0..8)
            .map(|t| {
                std::thread::spawn(move || {
                    for i in 0..500usize {
                        let size = 1 + (i * 37 + t * 101) % 20_000;
                        let mut b = take(size);
                        b.vec_mut().resize(size, t as u8);
                        let p = b.freeze();
                        assert_eq!(p.len(), size);
                        assert!(p.iter().all(|&x| x == t as u8));
                        let half = p.slice(0..size / 2);
                        drop(p);
                        assert!(half.iter().all(|&x| x == t as u8));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert!(mem::pool_held_bytes() >= 0);
        // bounded retention: every stripe of every class respects its cap
        let worst = (CLASSES * STRIPES * STRIPE_CAP) as i64 * (1 << MAX_CLASS_SHIFT);
        assert!(mem::pool_held_bytes() <= worst);
    }
}
