//! Mini property-testing framework (proptest is not in the offline vendor
//! set). Seeded case generation with failure seeds printed for replay:
//!
//! ```ignore
//! prop::check("chunk/reassemble identity", 200, |g| {
//!     let data = g.bytes(0, 1 << 16);
//!     let chunk = g.usize_in(1, 4096);
//!     prop::assert_that(reassemble(chunkify(&data, chunk)) == data, "mismatch")
//! });
//! ```

use crate::util::rng::Rng;

/// Per-case generator handed to the property closure.
pub struct Gen {
    rng: Rng,
    /// Case index (0..n); early cases bias small so shrinking is less needed.
    pub case: usize,
    total: usize,
}

impl Gen {
    /// Size hint in [0,1]: early cases are "small", later cases large.
    fn size(&self) -> f64 {
        if self.total <= 1 {
            1.0
        } else {
            (self.case as f64 / (self.total - 1) as f64).max(0.05)
        }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.f32()
    }

    /// usize in [lo, hi], biased toward lo for early cases.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        let span = ((hi - lo) as f64 * self.size()).ceil() as usize;
        lo + self.rng.usize_below(span.max(1).min(hi - lo + 1))
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    /// Random byte vector with length in [min_len, max_len].
    pub fn bytes(&mut self, min_len: usize, max_len: usize) -> Vec<u8> {
        let n = self.usize_in(min_len, max_len);
        (0..n).map(|_| (self.rng.next_u64() & 0xFF) as u8).collect()
    }

    /// Random f32 vector (finite values).
    pub fn f32s(&mut self, min_len: usize, max_len: usize) -> Vec<f32> {
        let n = self.usize_in(min_len, max_len);
        (0..n).map(|_| self.f32_in(-100.0, 100.0)).collect()
    }

    /// Random short ASCII identifier.
    pub fn ident(&mut self) -> String {
        let n = self.usize_in(1, 12);
        (0..n)
            .map(|_| (b'a' + (self.rng.below(26) as u8)) as char)
            .collect()
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.usize_below(items.len())]
    }

    /// Access the underlying RNG for custom sampling.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `n` cases of a property. Panics (with the failing seed) on the
/// first failure. Set `FEDFLARE_PROP_SEED` to replay a single case.
pub fn check(name: &str, n: usize, mut property: impl FnMut(&mut Gen) -> Result<(), String>) {
    if let Ok(seed_str) = std::env::var("FEDFLARE_PROP_SEED") {
        let seed: u64 = seed_str.parse().expect("FEDFLARE_PROP_SEED must be u64");
        let mut g = Gen {
            rng: Rng::new(seed),
            case: n.saturating_sub(1),
            total: n,
        };
        if let Err(msg) = property(&mut g) {
            panic!("property '{name}' failed on replay seed {seed}: {msg}");
        }
        return;
    }
    let base = 0xFEDF_1A2Eu64 ^ (name.len() as u64).wrapping_mul(0x9E37_79B9);
    for case in 0..n {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x2545_F491_4F6C_DD1D);
        let mut g = Gen {
            rng: Rng::new(seed),
            case,
            total: n,
        };
        if let Err(msg) = property(&mut g) {
            panic!(
                "property '{name}' failed at case {case}/{n}: {msg}\n\
                 replay with FEDFLARE_PROP_SEED={seed}"
            );
        }
    }
}

/// Helper: convert a boolean condition into the property result type.
pub fn assert_that(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Helper: approximate float equality with context.
pub fn assert_close(a: f64, b: f64, tol: f64, ctx: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{ctx}: {a} != {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always true", 50, |_g| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "replay with FEDFLARE_PROP_SEED=")]
    fn failing_property_reports_seed() {
        check("always false", 10, |_g| Err("nope".to_string()));
    }

    #[test]
    fn generators_respect_bounds() {
        check("bounds", 100, |g| {
            let x = g.usize_in(3, 17);
            assert_that((3..=17).contains(&x), format!("usize_in out of range: {x}"))?;
            let b = g.bytes(2, 64);
            assert_that(b.len() >= 2 && b.len() <= 64, "bytes len")?;
            let f = g.f32_in(-1.0, 1.0);
            assert_that((-1.0..=1.0).contains(&f), "f32 range")
        });
    }

    #[test]
    fn size_grows_with_case() {
        let mut first_len = None;
        let mut last_len = 0;
        check("sizing", 60, |g| {
            let v = g.bytes(0, 10_000);
            if g.case == 0 {
                first_len = Some(v.len());
            }
            last_len = v.len();
            Ok(())
        });
        // later cases are allowed to be big; early biased small
        assert!(first_len.unwrap() <= 10_000);
    }

    #[test]
    fn assert_close_tolerates() {
        assert!(assert_close(1.0, 1.0 + 1e-12, 1e-9, "x").is_ok());
        assert!(assert_close(1.0, 2.0, 1e-9, "x").is_err());
    }
}
