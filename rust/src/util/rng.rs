//! Deterministic PRNG + sampling distributions (the `rand` crate is not in
//! the offline vendor set).
//!
//! Core generator: **xoshiro256++** seeded via **SplitMix64** — fast,
//! well-tested statistical quality, trivially reproducible across runs,
//! which the experiment harness depends on (every figure is regenerated
//! from a fixed seed).

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Derive an independent stream (for per-client / per-task rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_u64(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (with Ahrens boost for shape<1).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = loop {
                let u = self.f64();
                if u > 0.0 {
                    break u;
                }
            };
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * ones(k)) — the paper's §4.2 heterogeneity knob.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut draws: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = draws.iter().sum();
        if sum <= 0.0 {
            // pathological underflow at tiny alpha: put all mass on one bin
            let i = self.usize_below(k);
            draws.iter_mut().for_each(|d| *d = 0.0);
            draws[i] = 1.0;
            return draws;
        }
        draws.iter_mut().for_each(|d| *d /= sum);
        draws
    }

    /// Sample an index from (unnormalized, non-negative) weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical needs positive mass");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.usize_below(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample `n` distinct indices from [0, pool) (partial Fisher–Yates).
    pub fn choose(&mut self, pool: usize, n: usize) -> Vec<usize> {
        assert!(n <= pool);
        let mut idx: Vec<usize> = (0..pool).collect();
        for i in 0..n {
            let j = i + self.usize_below(pool - i);
            idx.swap(i, j);
        }
        idx.truncate(n);
        idx
    }

    /// Fill with iid N(mean, std) f32 — the manifest `normal:<std>` init.
    pub fn fill_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for x in out.iter_mut() {
            *x = self.normal_f32(mean, std);
        }
    }
}

#[inline]
fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let mut c = Rng::new(8);
        let xa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let xc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(xa, xb);
        assert_ne!(xa, xc);
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = r.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(4);
        for shape in [0.3, 1.0, 5.0] {
            let n = 20_000;
            let mean = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!((mean - shape).abs() < 0.1 * shape.max(1.0), "{shape} {mean}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one_and_alpha_controls_spread() {
        let mut r = Rng::new(5);
        for alpha in [0.1, 1.0, 10.0] {
            let p = r.dirichlet(alpha, 5);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
        // small alpha => concentrated; large alpha => near-uniform (on average)
        let max_small: f64 = (0..200)
            .map(|_| {
                r.dirichlet(0.05, 5)
                    .into_iter()
                    .fold(0.0f64, f64::max)
            })
            .sum::<f64>()
            / 200.0;
        let max_large: f64 = (0..200)
            .map(|_| r.dirichlet(50.0, 5).into_iter().fold(0.0f64, f64::max))
            .sum::<f64>()
            / 200.0;
        assert!(max_small > 0.8, "{max_small}");
        assert!(max_large < 0.4, "{max_large}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(6);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        let total = 30_000f64;
        assert!((counts[0] as f64 / total - 0.1).abs() < 0.02);
        assert!((counts[2] as f64 / total - 0.7).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(7);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn choose_returns_distinct() {
        let mut r = Rng::new(8);
        let picked = r.choose(10, 4);
        assert_eq!(picked.len(), 4);
        let mut s = picked.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(
            (0..4).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..4).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }
}
