//! Delta-native payload integration tests — the acceptance criteria of
//! the sparse/quantized update path, over inproc AND tcp:
//!
//! (a) a job configured with delta updates (and with int8-quantized
//!     records) converges to the same final model as the dense f32 run;
//! (b) a LoRA-style job (trainable filter selecting a sliver of the
//!     model) trains only the adapters and moves >=10x fewer payload
//!     bytes per round than dense f32;
//! (c) the manifest/base-version stamp survives transport;
//! (d) delta checkpoint resume is byte-identical across a server
//!     kill/restart, including a restart landing mid-chain between full
//!     snapshots.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use fedflare::config::{ClientSpec, JobConfig};
use fedflare::coordinator::{
    Communicator, Controller, JobRequest, JobScheduler, JobStatus, SamplePolicy,
    ScatterAndGather, ServerCtx, StreamingMean,
};
use fedflare::executor::{Executor, StreamTestExecutor};
use fedflare::message::FlMessage;
use fedflare::persist::JobStore;
use fedflare::sim::{DriverKind, Fleet};
use fedflare::streaming::Messenger;
use fedflare::tensor::{RecordEnc, Tensor, TensorDict};

fn results_dir() -> String {
    let d = std::env::temp_dir().join("fedflare_delta_tests");
    let _ = std::fs::create_dir_all(&d);
    d.to_string_lossy().to_string()
}

fn clients(n: usize) -> Vec<ClientSpec> {
    (0..n)
        .map(|i| ClientSpec {
            name: format!("site-{:02}", i + 1),
            bandwidth_bps: 0,
            partition: i,
        })
        .collect()
}

fn delta_job(name: &str, n_clients: usize, rounds: usize) -> JobConfig {
    let mut job = JobConfig::named(name, "stream_test");
    job.rounds = rounds;
    job.clients = clients(n_clients);
    job.min_clients = n_clients;
    job.stream.chunk_bytes = 4096;
    job
}

fn wait_until(timeout: Duration, mut f: impl FnMut() -> bool) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < timeout {
        if f() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    f()
}

type SharedOut = Arc<Mutex<Option<(Vec<u8>, usize)>>>;

/// Captures the final model bytes + completed-round count of the inner
/// workflow (scheduled controllers move into job threads).
struct Reporting {
    inner: ScatterAndGather,
    out: SharedOut,
}

impl Controller for Reporting {
    fn name(&self) -> &'static str {
        "reporting"
    }
    fn run(&mut self, comm: &mut Communicator, ctx: &mut ServerCtx) -> anyhow::Result<()> {
        let result = self.inner.run(comm, ctx);
        *self.out.lock().unwrap() =
            Some((self.inner.model.to_bytes(), self.inner.history.len()));
        result
    }
}

/// Submit an add-delta job wired exactly as `build_sag` wires production
/// jobs: the server aggregator mirrors the job's sparse/delta knobs and
/// checkpoint cadence, the executors mirror its trainable filter.
fn submit_delta_job(
    sched: &JobScheduler,
    job: JobConfig,
    keys: usize,
    elems: usize,
    step: f32,
    work_ms: u64,
) -> (u32, SharedOut) {
    let initial = StreamTestExecutor::build_model(keys, elems, 1.0);
    let policy = SamplePolicy {
        min_clients: job.min_clients,
        sample_count: job.clients.len(),
        round_timeout: None,
    };
    let agg = Box::new(StreamingMean::new(&initial));
    let mut ctl = ScatterAndGather::with_aggregator(initial, job.rounds, policy, agg);
    ctl.task_name = "stream_test".into();
    ctl.checkpoint_every = job.checkpoint_every_n_rounds;
    if job.sparse_updates() {
        ctl.set_sparse(job.delta_updates).unwrap();
    }
    let out: SharedOut = Arc::new(Mutex::new(None));
    let reporting = Reporting {
        inner: ctl,
        out: out.clone(),
    };
    let trainable = job.trainable_filter.clone();
    let emit_delta = job.delta_updates;
    let factory: fedflare::coordinator::OwnedExecutorFactory = Box::new(move |_i, _s| {
        let mut e = StreamTestExecutor::new(None, step);
        e.work_ms = work_ms;
        e.trainable = trainable.clone();
        e.emit_delta = emit_delta;
        Ok(Box::new(e) as Box<dyn Executor>)
    });
    let id = sched.submit(JobRequest {
        job,
        controller: Box::new(reporting),
        factory,
    });
    (id, out)
}

/// Run one job to completion on a fresh fleet; returns the final model
/// bytes.
fn run_to_completion(kind: DriverKind, job: JobConfig, keys: usize, elems: usize) -> Vec<u8> {
    let specs = job.clients.clone();
    let fleet = Fleet::connect(&specs, kind, &Default::default()).unwrap();
    let sched = JobScheduler::new(fleet.clone(), 1, &results_dir());
    let (id, out) = submit_delta_job(&sched, job, keys, elems, 0.5, 0);
    let outcome = sched.wait(id);
    assert_eq!(outcome.status, JobStatus::Completed, "{:?}", outcome.error);
    sched.drain();
    fleet.shutdown();
    out.lock().unwrap().take().unwrap().0
}

/// (a) Delta-update and int8-delta jobs land on the dense run's model.
/// Equality is bitwise here: the synthetic workload's per-round deltas
/// are constant within each tensor, which the affine codec represents
/// exactly (degenerate range -> every element decodes to `min`), so even
/// the quantized run has zero codec error.
fn sparse_and_quantized_match_dense(kind: DriverKind, tag: &str) {
    let rounds = 3;
    let oracle = 1.0 + rounds as f32 * 0.5;
    let dense = run_to_completion(kind, delta_job(&format!("dp_dense_{tag}"), 2, rounds), 4, 256);

    let mut job = delta_job(&format!("dp_delta_{tag}"), 2, rounds);
    job.delta_updates = true;
    let delta = run_to_completion(kind, job, 4, 256);
    assert_eq!(delta, dense, "delta-update run diverged from dense");

    let mut job = delta_job(&format!("dp_int8_{tag}"), 2, rounds);
    job.delta_updates = true;
    job.update_codec = RecordEnc::Int8;
    let q8 = run_to_completion(kind, job, 4, 256);
    assert_eq!(q8, dense, "int8 delta run diverged from dense");

    let model = TensorDict::from_bytes(&dense).unwrap();
    let v = model.get("key_000").unwrap().as_f32().unwrap();
    assert!(
        v.iter().all(|&x| (x - oracle).abs() < 1e-5),
        "expected {oracle}, got {}",
        v[0]
    );
}

#[test]
fn sparse_and_quantized_match_dense_inproc() {
    sparse_and_quantized_match_dense(DriverKind::InProc, "ip");
}

#[test]
fn sparse_and_quantized_match_dense_tcp() {
    sparse_and_quantized_match_dense(DriverKind::Tcp, "tcp");
}

/// (b) LoRA-style filter: only the adapter tensors train; the rest of
/// the global carries forward untouched through the sparse fold.
fn lora_filter_trains_only_adapters(kind: DriverKind, tag: &str) {
    let rounds = 3;
    let mut job = delta_job(&format!("dp_lora_{tag}"), 2, rounds);
    job.trainable_filter = vec!["key_00".into()]; // key_000..key_009 of 16
    job.delta_updates = true;
    let bytes = run_to_completion(kind, job, 16, 64);
    let model = TensorDict::from_bytes(&bytes).unwrap();
    for i in 0..16 {
        let name = format!("key_{i:03}");
        let v = model.get(&name).unwrap().as_f32().unwrap();
        let want = if i < 10 { 1.0 + rounds as f32 * 0.5 } else { 1.0 };
        assert!(
            v.iter().all(|&x| (x - want).abs() < 1e-5),
            "{name}: expected {want}, got {}",
            v[0]
        );
    }
}

#[test]
fn lora_filter_trains_only_adapters_inproc() {
    lora_filter_trains_only_adapters(DriverKind::InProc, "ip");
}

#[test]
fn lora_filter_trains_only_adapters_tcp() {
    lora_filter_trains_only_adapters(DriverKind::Tcp, "tcp");
}

/// (b) Payload math at the message layer: a LoRA-sliver update moves
/// >=10x fewer bytes than the dense f32 model, int8 ~4x fewer, int4 ~8x
/// fewer, and sparse+int4 compounds past 100x.
#[test]
fn lora_sparse_and_quantized_payload_ratios() {
    // 64 keys x 16 kB = 1 MB dense model; 3 adapter keys ~= 4.7% <= 5%
    let full = StreamTestExecutor::build_model(64, 4096, 1.0);
    let dense_msg = FlMessage::result("stream_test", 0, "site-01", full.clone());
    let dense = dense_msg.v2_encoded_len(RecordEnc::Raw);

    let mut adapters = TensorDict::new();
    for name in ["key_000", "key_001", "key_002"] {
        adapters.insert(name, full.get(name).unwrap().clone());
    }
    let sparse_msg =
        FlMessage::result("stream_test", 0, "site-01", adapters).with_manifest(0, true);
    let sparse = sparse_msg.v2_encoded_len(RecordEnc::Raw);
    assert!(
        sparse * 10 <= dense,
        "LoRA update {sparse} B is not >=10x under dense {dense} B"
    );

    let q8 = dense_msg.v2_encoded_len(RecordEnc::Int8);
    assert!(
        (q8 as f64) <= dense as f64 / 3.8,
        "int8 {q8} B is not ~4x under dense {dense} B"
    );
    let q4 = dense_msg.v2_encoded_len(RecordEnc::Int4);
    assert!(
        (q4 as f64) <= dense as f64 / 7.5,
        "int4 {q4} B is not ~8x under dense {dense} B"
    );
    let both = sparse_msg.v2_encoded_len(RecordEnc::Int4);
    assert!(
        both * 100 <= dense,
        "sparse+int4 {both} B vs dense {dense} B"
    );
}

/// (b) And the same holds for actual transported bytes, measured at the
/// messenger's payload counters rather than computed lengths.
#[test]
fn quantized_transport_bytes_shrink_on_the_wire() {
    let (a, b) = fedflare::sfm::inproc::pair(256, "delta_bytes");
    let mut tx = Messenger::new(Box::new(a), 64 << 10, 1);
    let mut rx = Messenger::new(Box::new(b), 64 << 10, 2);
    let model = StreamTestExecutor::build_model(8, 4096, 1.0); // 128 kB
    let msg = FlMessage::result("stream_test", 0, "c", model);
    tx.send_msg(&msg).unwrap();
    rx.recv_msg().unwrap();
    let raw = tx.sent_bytes;
    tx.send_msg_enc(&msg, RecordEnc::Int8).unwrap();
    rx.recv_msg().unwrap();
    let q8 = tx.sent_bytes - raw;
    assert!(
        (q8 as f64) < raw as f64 / 3.5,
        "int8 wire bytes {q8} vs raw {raw}"
    );
    assert_eq!(tx.sent_bytes, rx.recv_bytes);
}

/// (c) The per-message tensor manifest and base-version stamp survive a
/// quantized transport round-trip intact.
#[test]
fn manifest_metadata_survives_transport() {
    let (a, b) = fedflare::sfm::inproc::pair(64, "delta_manifest");
    let mut tx = Messenger::new(Box::new(a), 4096, 1);
    let mut rx = Messenger::new(Box::new(b), 4096, 2);
    let mut body = TensorDict::new();
    body.insert("lora_a.0", Tensor::f32(vec![4], vec![0.25; 4]));
    let msg = FlMessage::result("train", 5, "site-01", body).with_manifest(5, true);
    assert!(msg.manifest_complete());
    tx.send_msg_enc(&msg, RecordEnc::Int4).unwrap();
    let got = rx.recv_msg().unwrap();
    assert_eq!(got.base_version(), Some(5));
    assert!(got.is_delta());
    assert!(got.manifest_complete());
    assert_eq!(got.manifest().unwrap(), vec!["lora_a.0".to_string()]);
    // int4 on a constant tensor is exact (degenerate affine range)
    assert_eq!(got.body.get("lora_a.0").unwrap().as_f32().unwrap(), &[0.25; 4]);
}

/// Delta-checkpoint files of `job` currently on disk under `state_dir`.
fn delta_files(state_dir: &std::path::Path) -> usize {
    std::fs::read_dir(state_dir.join("jobs"))
        .map(|it| {
            it.flatten()
                .filter(|e| e.file_name().to_string_lossy().contains(".ckpt.d"))
                .count()
        })
        .unwrap_or(0)
}

/// (d) Durable resume through the delta chain: kill the server while the
/// latest checkpoint is a *delta* (mid-chain, between full snapshots),
/// restart over the same store, and land byte-identical to an
/// uninterrupted run — with delta updates and the int8 codec live.
fn delta_checkpoint_resume_byte_identical(kind: DriverKind, tag: &str) {
    let rounds = 8;
    let name = format!("dp_resume_{tag}");
    let mk_job = || {
        let mut job = delta_job(&name, 2, rounds);
        job.delta_updates = true;
        job.update_codec = RecordEnc::Int8;
        // full snapshots at rounds 0 and 7 only: every intermediate
        // round persists as a link of the delta chain
        job.checkpoint_every_n_rounds = 7;
        job
    };

    // the uninterrupted reference (no store)
    let reference = {
        let fleet = Fleet::connect(&clients(2), kind, &Default::default()).unwrap();
        let sched = JobScheduler::new(fleet.clone(), 1, &results_dir());
        let (id, out) = submit_delta_job(&sched, mk_job(), 2, 512, 0.5, 40);
        assert_eq!(sched.wait(id).status, JobStatus::Completed);
        sched.drain();
        fleet.shutdown();
        out.lock().unwrap().take().unwrap().0
    };

    let state_dir = std::env::temp_dir().join(format!("fedflare_delta_resume_{tag}"));
    let _ = std::fs::remove_dir_all(&state_dir);
    let store = Arc::new(JobStore::open(&state_dir).unwrap());

    // phase 1: run with the store, abort once a delta link is on disk
    // (abort + teardown stands in for SIGKILL)
    {
        let fleet = Fleet::connect(&clients(2), kind, &Default::default()).unwrap();
        let sched =
            JobScheduler::with_store(fleet.clone(), 1, &results_dir(), Some(store.clone()));
        let (id, _out) = submit_delta_job(&sched, mk_job(), 2, 512, 0.5, 40);
        assert!(
            wait_until(Duration::from_secs(20), || delta_files(&state_dir) > 0),
            "no delta checkpoint appeared"
        );
        sched.abort(id);
        let _ = sched.wait(id);
        sched.drain();
        fleet.shutdown();
    }
    assert!(delta_files(&state_dir) > 0, "restart must land mid-chain");
    let ck = store
        .load_round(&name)
        .unwrap()
        .expect("chain readable after the crash");
    assert!(ck.round >= 1 && ck.round < rounds, "round {}", ck.round);

    // phase 2: fresh fleet + scheduler over the same store — the job
    // replays the chain, resumes mid-run, and matches the reference
    {
        let fleet = Fleet::connect(&clients(2), kind, &Default::default()).unwrap();
        let sched =
            JobScheduler::with_store(fleet.clone(), 1, &results_dir(), Some(store.clone()));
        let (id, out) = submit_delta_job(&sched, mk_job(), 2, 512, 0.5, 40);
        let outcome = sched.wait(id);
        assert_eq!(outcome.status, JobStatus::Completed, "{:?}", outcome.error);
        let (bytes, hist) = out.lock().unwrap().take().unwrap();
        assert_eq!(
            bytes, reference,
            "resumed final model diverged from the uninterrupted run"
        );
        assert!(
            hist < rounds,
            "resume re-ran every round ({hist} of {rounds}) — chain not used"
        );
        sched.drain();
        fleet.shutdown();
    }
    let _ = std::fs::remove_dir_all(&state_dir);
}

#[test]
fn delta_checkpoint_resume_byte_identical_inproc() {
    delta_checkpoint_resume_byte_identical(DriverKind::InProc, "ip");
}

#[test]
fn delta_checkpoint_resume_byte_identical_tcp() {
    delta_checkpoint_resume_byte_identical(DriverKind::Tcp, "tcp");
}
