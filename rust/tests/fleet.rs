//! Fleet control-plane integration tests: elastic membership under
//! churn (kill / revive while jobs run), heartbeat-driven liveness, and
//! durable checkpoint/resume — the acceptance criteria of the control
//! plane:
//!
//! (a) a client killed mid-round is marked Suspect and the round still
//!     finalizes at quorum;
//! (b) a client that rejoins is sampled in a later round and the job
//!     completes;
//! (c) a server killed between rounds resumes from the last round
//!     checkpoint and produces a final model byte-identical to an
//!     uninterrupted run — over inproc AND tcp.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use fedflare::config::{ClientSpec, FleetConfig, JobConfig};
use fedflare::coordinator::{
    Communicator, Controller, JobRequest, JobScheduler, JobStatus, SamplePolicy,
    ScatterAndGather, ServerCtx, StreamingMean,
};
use fedflare::executor::{Executor, StreamTestExecutor};
use fedflare::fleet::ClientState;
use fedflare::persist::JobStore;
use fedflare::sim::{DriverKind, Fleet};

fn results_dir() -> String {
    let d = std::env::temp_dir().join("fedflare_fleet_tests");
    let _ = std::fs::create_dir_all(&d);
    d.to_string_lossy().to_string()
}

fn fleet_clients(n: usize) -> Vec<ClientSpec> {
    (0..n)
        .map(|i| ClientSpec {
            name: format!("site-{:02}", i + 1),
            bandwidth_bps: 0,
            partition: i,
        })
        .collect()
}

/// Tight control-plane knobs so churn is observed within milliseconds,
/// not the production-grade default deadlines.
fn tight_cfg() -> FleetConfig {
    FleetConfig {
        heartbeat_interval_s: 0.05,
        suspect_after_s: 0.3,
        gone_after_s: 30.0,
    }
}

/// Job config: `n` fleet clients, chunked small so streams span frames.
fn churn_job(name: &str, n_clients: usize, rounds: usize, min_clients: usize) -> JobConfig {
    let mut job = JobConfig::named(name, "stream_test");
    job.rounds = rounds;
    job.clients = fleet_clients(n_clients);
    job.min_clients = min_clients;
    job.stream.chunk_bytes = 4096;
    job
}

type JobSummary = (Vec<u8>, Vec<(usize, Vec<String>)>);
type SharedSummary = Arc<Mutex<Option<JobSummary>>>;

/// Captures the final model bytes + per-round participant names of the
/// inner workflow (scheduled controllers move into job threads).
struct Reporting {
    inner: ScatterAndGather,
    out: SharedSummary,
}

impl Controller for Reporting {
    fn name(&self) -> &'static str {
        "reporting"
    }
    fn run(&mut self, comm: &mut Communicator, ctx: &mut ServerCtx) -> anyhow::Result<()> {
        let result = self.inner.run(comm, ctx);
        let hist = self
            .inner
            .history
            .iter()
            .map(|h| {
                (
                    h.round,
                    h.per_client.iter().map(|(n, ..)| n.clone()).collect::<Vec<_>>(),
                )
            })
            .collect();
        *self.out.lock().unwrap() = Some((self.inner.model.to_bytes(), hist));
        result
    }
}

/// Submit an add-delta job whose workflow samples every listed client
/// (`sample_count = n`) with quorum `min_clients` — the shape churn
/// tolerance needs: a dead site's failure is absorbed while the quorum
/// holds.
fn submit_churn_job(
    sched: &JobScheduler,
    job: JobConfig,
    keys: usize,
    elems: usize,
    delta: f32,
    work_ms: u64,
) -> (u32, SharedSummary) {
    let initial = StreamTestExecutor::build_model(keys, elems, 1.0);
    let policy = SamplePolicy {
        min_clients: job.min_clients,
        sample_count: job.clients.len(),
        round_timeout: None,
    };
    let agg = Box::new(StreamingMean::new(&initial));
    let mut ctl = ScatterAndGather::with_aggregator(initial, job.rounds, policy, agg);
    ctl.task_name = "stream_test".into();
    let out: SharedSummary = Arc::new(Mutex::new(None));
    let reporting = Reporting {
        inner: ctl,
        out: out.clone(),
    };
    let factory: fedflare::coordinator::OwnedExecutorFactory = Box::new(move |_i, _s| {
        let mut e = StreamTestExecutor::new(None, delta);
        e.work_ms = work_ms;
        Ok(Box::new(e) as Box<dyn Executor>)
    });
    let id = sched.submit(JobRequest {
        job,
        controller: Box::new(reporting),
        factory,
    });
    (id, out)
}

/// Poll until `f` returns true or the timeout passes.
fn wait_until(timeout: Duration, mut f: impl FnMut() -> bool) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < timeout {
        if f() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    f()
}

/// Completed FedAvg rounds of `job` so far, read off its metrics event
/// log — the observable the churn tests pace on, so kills land mid-run
/// rather than at absolute times (slow CI machines shift everything).
fn rounds_done(job: &str) -> usize {
    let path = std::path::Path::new(&results_dir()).join(format!("{job}.events.jsonl"));
    std::fs::read_to_string(path)
        .map(|s| s.matches("fedavg_round").count())
        .unwrap_or(0)
}

/// (a) Kill a client mid-round: it is marked Suspect, the in-flight
/// round finalizes at quorum, later rounds sample only the live pool,
/// and the job completes on its oracle.
fn kill_mid_round_finalizes_at_quorum(kind: DriverKind, tag: &str) {
    let fleet =
        Fleet::connect_with(&fleet_clients(3), kind, &Default::default(), tight_cfg()).unwrap();
    let sched = JobScheduler::new(fleet.clone(), 2, &results_dir());
    // 2 keys x 150 ms of simulated compute per round: once round 0's
    // event lands, the next round is in its compute phase for ~300 ms —
    // the kill below lands mid-round, before results stream
    let name = format!("fleet_kill_{tag}");
    let job = churn_job(&name, 3, 3, 2);
    let (id, out) = submit_churn_job(&sched, job, 2, 256, 0.5, 150);
    assert!(
        wait_until(Duration::from_secs(20), || rounds_done(&name) >= 1),
        "round 0 never completed"
    );
    fleet.kill_client("site-03").unwrap();
    // the kill demotes the client out of the live view immediately
    assert_eq!(
        fleet.client_state("site-03"),
        Some(ClientState::Suspect),
        "killed client must be Suspect"
    );
    let outcome = sched.wait(id);
    assert_eq!(outcome.status, JobStatus::Completed, "{:?}", outcome.error);
    let (model_bytes, hist) = out.lock().unwrap().take().unwrap();
    // every round completed; the oracle holds because all deltas are
    // equal, so the mean is delta regardless of how many sites folded
    assert_eq!(hist.len(), 3);
    let model = fedflare::tensor::TensorDict::from_bytes(&model_bytes).unwrap();
    let v = model.get("key_000").unwrap().as_f32().unwrap();
    assert!(
        v.iter().all(|&x| (x - 2.5).abs() < 1e-5),
        "expected 1.0 + 3*0.5, got {}",
        v[0]
    );
    // rounds after the kill sampled only the live pool (2 sites); the
    // killed site never reappears
    let last = &hist[hist.len() - 1].1;
    assert_eq!(last.len(), 2, "last round folded the 2 live sites: {last:?}");
    assert!(
        !last.contains(&"site-03".to_string()),
        "dead site sampled after its kill: {last:?}"
    );
    sched.drain();
    fleet.shutdown();
}

#[test]
fn kill_mid_round_finalizes_at_quorum_inproc() {
    kill_mid_round_finalizes_at_quorum(DriverKind::InProc, "ip");
}

#[test]
fn kill_mid_round_finalizes_at_quorum_tcp() {
    kill_mid_round_finalizes_at_quorum(DriverKind::Tcp, "tcp");
}

/// (b) Kill then revive a client while its job runs: the rejoin
/// handshake re-deploys it, it turns Live again, later rounds sample it,
/// and the job completes on its oracle.
fn rejoin_is_sampled_in_a_later_round(kind: DriverKind, tag: &str) {
    let fleet =
        Fleet::connect_with(&fleet_clients(3), kind, &Default::default(), tight_cfg()).unwrap();
    let sched = JobScheduler::new(fleet.clone(), 2, &results_dir());
    // 2 keys x 100 ms -> ~200 ms rounds; 8 rounds leave plenty of
    // runway after the revive (paced on the round events, not on
    // absolute time, so a loaded machine shifts nothing)
    let rounds = 8;
    let name = format!("fleet_rejoin_{tag}");
    let job = churn_job(&name, 3, rounds, 2);
    let (id, out) = submit_churn_job(&sched, job, 2, 256, 0.5, 100);
    assert!(
        wait_until(Duration::from_secs(20), || rounds_done(&name) >= 1),
        "round 0 never completed"
    );
    fleet.kill_client("site-03").unwrap();
    assert_eq!(fleet.client_state("site-03"), Some(ClientState::Suspect));
    // let at least one full round run without the killed site...
    assert!(
        wait_until(Duration::from_secs(20), || rounds_done(&name) >= 3),
        "rounds stalled after the kill"
    );
    fleet.revive_client("site-03").unwrap();
    assert!(
        wait_until(Duration::from_secs(2), || fleet.client_state("site-03")
            == Some(ClientState::Live)),
        "revived client never turned Live"
    );
    let outcome = sched.wait(id);
    assert_eq!(outcome.status, JobStatus::Completed, "{:?}", outcome.error);
    let (model_bytes, hist) = out.lock().unwrap().take().unwrap();
    assert_eq!(hist.len(), rounds);
    let model = fedflare::tensor::TensorDict::from_bytes(&model_bytes).unwrap();
    let v = model.get("key_000").unwrap().as_f32().unwrap();
    let oracle = 1.0 + rounds as f32 * 0.5;
    assert!(
        v.iter().all(|&x| (x - oracle).abs() < 1e-4),
        "expected {oracle}, got {}",
        v[0]
    );
    // the timeline the control plane promises: a round without the
    // killed site, then — after the revive — a round folding it again
    let without = hist
        .iter()
        .position(|(_, names)| !names.contains(&"site-03".to_string()))
        .expect("no round ran without the killed site");
    let back = hist
        .iter()
        .skip(without)
        .any(|(_, names)| names.contains(&"site-03".to_string()));
    assert!(back, "revived site never sampled again: {hist:?}");
    sched.drain();
    fleet.shutdown();
}

#[test]
fn rejoin_is_sampled_in_a_later_round_inproc() {
    rejoin_is_sampled_in_a_later_round(DriverKind::InProc, "ip");
}

#[test]
fn rejoin_is_sampled_in_a_later_round_tcp() {
    rejoin_is_sampled_in_a_later_round(DriverKind::Tcp, "tcp");
}

/// Registry-backed admission: a job naming a dead client stays queued
/// until the client rejoins, then dispatches automatically (the fleet's
/// epoch-change listener kicks the scheduler).
#[test]
fn queued_job_waits_for_its_client_and_admits_on_rejoin() {
    let fleet = Fleet::connect_with(
        &fleet_clients(2),
        DriverKind::InProc,
        &Default::default(),
        tight_cfg(),
    )
    .unwrap();
    let sched = JobScheduler::new(fleet.clone(), 2, &results_dir());
    fleet.kill_client("site-02").unwrap();
    let job = churn_job("fleet_admission", 2, 2, 2);
    let (id, out) = submit_churn_job(&sched, job, 2, 64, 0.5, 0);
    // not admissible while site-02 is down
    std::thread::sleep(Duration::from_millis(150));
    assert_eq!(sched.status(id), Some(JobStatus::Queued));
    fleet.revive_client("site-02").unwrap();
    let outcome = sched.wait(id);
    assert_eq!(outcome.status, JobStatus::Completed, "{:?}", outcome.error);
    assert!(out.lock().unwrap().is_some());
    sched.drain();
    fleet.shutdown();
}

/// (c) Durable resume: run a job with a state store, kill the server
/// after at least one round checkpointed, restart everything (fresh
/// fleet, fresh scheduler, same store) — the job resumes from its last
/// completed round and the final model is byte-identical to an
/// uninterrupted run.
fn resume_is_byte_identical(kind: DriverKind, tag: &str) {
    let rounds = 4;
    let job_name = format!("fleet_resume_{tag}");

    // the uninterrupted reference (no store)
    let reference = {
        let fleet =
            Fleet::connect_with(&fleet_clients(2), kind, &Default::default(), tight_cfg())
                .unwrap();
        let sched = JobScheduler::new(fleet.clone(), 2, &results_dir());
        let job = churn_job(&job_name, 2, rounds, 2);
        let (id, out) = submit_churn_job(&sched, job, 2, 512, 0.5, 40);
        assert_eq!(sched.wait(id).status, JobStatus::Completed);
        sched.drain();
        fleet.shutdown();
        out.lock().unwrap().take().unwrap().0
    };

    let state_dir = std::env::temp_dir().join(format!("fedflare_fleet_resume_{tag}"));
    let _ = std::fs::remove_dir_all(&state_dir);
    let store = Arc::new(JobStore::open(&state_dir).unwrap());

    // phase 1: run with the store, kill the "server" once a round
    // checkpoint exists (abort + teardown stands in for SIGKILL —
    // whatever was mid-flight is lost, the checkpoint survives)
    {
        let fleet =
            Fleet::connect_with(&fleet_clients(2), kind, &Default::default(), tight_cfg())
                .unwrap();
        let sched =
            JobScheduler::with_store(fleet.clone(), 2, &results_dir(), Some(store.clone()));
        let job = churn_job(&job_name, 2, rounds, 2);
        let (id, _out) = submit_churn_job(&sched, job, 2, 512, 0.5, 40);
        assert!(
            wait_until(Duration::from_secs(20), || store
                .load_round(&job_name)
                .unwrap()
                .is_some()),
            "no round checkpoint appeared"
        );
        sched.abort(id);
        let _ = sched.wait(id);
        sched.drain();
        fleet.shutdown();
    }
    let ck = store
        .load_round(&job_name)
        .unwrap()
        .expect("checkpoint survives the crash");
    assert!(ck.round < rounds, "checkpoint round in range");

    // phase 2: fresh fleet + scheduler over the same store — the job
    // resumes from the checkpoint and completes
    {
        let fleet =
            Fleet::connect_with(&fleet_clients(2), kind, &Default::default(), tight_cfg())
                .unwrap();
        let sched =
            JobScheduler::with_store(fleet.clone(), 2, &results_dir(), Some(store.clone()));
        let job = churn_job(&job_name, 2, rounds, 2);
        let (id, out) = submit_churn_job(&sched, job, 2, 512, 0.5, 40);
        let outcome = sched.wait(id);
        assert_eq!(outcome.status, JobStatus::Completed, "{:?}", outcome.error);
        let (model_bytes, hist) = out.lock().unwrap().take().unwrap();
        assert_eq!(
            model_bytes, reference,
            "resumed final model diverged from the uninterrupted run"
        );
        assert!(
            hist.len() < rounds,
            "resume re-ran every round (history {} of {rounds}) — no checkpoint used",
            hist.len()
        );
        // the manifest records the completion for the next recovery
        assert_eq!(store.status(&job_name).as_deref(), Some("completed"));
        sched.drain();
        fleet.shutdown();
    }
    let _ = std::fs::remove_dir_all(&state_dir);
}

#[test]
fn resume_is_byte_identical_inproc() {
    resume_is_byte_identical(DriverKind::InProc, "ip");
}

#[test]
fn resume_is_byte_identical_tcp() {
    resume_is_byte_identical(DriverKind::Tcp, "tcp");
}

/// An elastic join: a brand-new client added while the fleet serves is
/// admissible for jobs submitted afterwards.
#[test]
fn added_client_serves_new_jobs() {
    let fleet = Fleet::connect_with(
        &fleet_clients(2),
        DriverKind::InProc,
        &Default::default(),
        tight_cfg(),
    )
    .unwrap();
    let sched = JobScheduler::new(fleet.clone(), 2, &results_dir());
    assert_eq!(fleet.n_clients(), 2);
    fleet
        .add_client(&ClientSpec {
            name: "site-03".into(),
            bandwidth_bps: 0,
            partition: 2,
        })
        .unwrap();
    assert_eq!(fleet.n_clients(), 3);
    assert!(wait_until(Duration::from_secs(2), || {
        fleet.client_state("site-03") == Some(ClientState::Live)
    }));
    let job = churn_job("fleet_added", 3, 2, 3);
    let (id, out) = submit_churn_job(&sched, job, 2, 128, 0.5, 0);
    let outcome = sched.wait(id);
    assert_eq!(outcome.status, JobStatus::Completed, "{:?}", outcome.error);
    let (_, hist) = out.lock().unwrap().take().unwrap();
    assert!(
        hist.iter().all(|(_, names)| names.contains(&"site-03".to_string())),
        "added client never folded: {hist:?}"
    );
    sched.drain();
    fleet.shutdown();
}
