//! Integration tests: complete FL jobs over both SFM drivers, runtime +
//! coordinator + executor composed, with the real AOT artifacts when
//! available (tests gracefully skip if `make artifacts` has not run).

use std::time::Duration;

use fedflare::config::{AggregatorSpec, ClientSpec, FilterSpec, JobConfig};
use fedflare::coordinator::{
    build_aggregator, Aggregator, CyclicWeightTransfer, FedAvg, FederatedEval, SamplePolicy,
    ScatterAndGather,
};
use fedflare::executor::{Executor, StreamTestExecutor};
use fedflare::message::FlMessage;
use fedflare::runtime::RuntimeClient;
use fedflare::sim::{self, DriverKind};
use fedflare::tensor::TensorDict;
use fedflare::util::json::Json;

fn results_dir() -> String {
    let d = std::env::temp_dir().join("fedflare_integration");
    let _ = std::fs::create_dir_all(&d);
    d.to_string_lossy().to_string()
}

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

fn three_clients() -> Vec<ClientSpec> {
    (0..3)
        .map(|i| ClientSpec {
            name: format!("site-{}", i + 1),
            bandwidth_bps: 0,
            partition: i,
        })
        .collect()
}

// ---------------------------------------------------------------- core FL

#[test]
fn fedavg_stream_test_over_both_drivers_same_result() {
    let run = |kind| {
        let mut job = JobConfig::named("it_drivers", "stream_test");
        job.rounds = 3;
        job.min_clients = 2;
        job.stream.chunk_bytes = 8192;
        let initial = StreamTestExecutor::build_model(4, 2048, 1.0);
        let mut ctl = FedAvg::new(initial, 3, 2);
        ctl.task_name = "stream_test".into();
        let mut f: Box<sim::ExecutorFactory> = Box::new(|_i, _s| {
            Ok(Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>)
        });
        sim::run_job(&job, kind, &mut ctl, &mut f, &results_dir()).unwrap();
        ctl.model
    };
    let inproc = run(DriverKind::InProc);
    let tcp = run(DriverKind::Tcp);
    assert_eq!(inproc, tcp, "driver must not affect results");
    let v = inproc.get("key_000").unwrap().as_f32().unwrap();
    assert!((v[0] - 2.5).abs() < 1e-5);
}

#[test]
fn cyclic_weight_transfer_visits_all_clients() {
    let mut job = JobConfig::named("it_cyclic", "stream_test");
    job.rounds = 2;
    job.clients = three_clients();
    job.min_clients = 3;
    let initial = StreamTestExecutor::build_model(2, 512, 0.0);
    let mut ctl = CyclicWeightTransfer::new(initial, 2);
    let mut f: Box<sim::ExecutorFactory> =
        Box::new(|_i, _s| Ok(Box::new(StreamTestExecutor::new(None, 1.0)) as Box<dyn Executor>));
    sim::run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
    // 2 rounds x 3 clients, each adds 1.0 => model value 6.0
    let v = ctl.model.get("key_000").unwrap().as_f32().unwrap();
    assert!((v[0] - 6.0).abs() < 1e-5);
    assert_eq!(ctl.trace.len(), 6);
    // every client visited each round, in order
    let names: Vec<&str> = ctl.trace.iter().map(|(_, c, _)| c.as_str()).collect();
    assert_eq!(
        names,
        vec!["site-1", "site-2", "site-3", "site-1", "site-2", "site-3"]
    );
}

/// Executor reporting a fixed val metric, for FederatedEval.
struct FixedEval(f64);
impl Executor for FixedEval {
    fn execute(&mut self, task: &FlMessage) -> anyhow::Result<FlMessage> {
        Ok(FlMessage::result(&task.task, task.round, "", TensorDict::new())
            .with_meta("val_loss", Json::num(self.0))
            .with_meta("val_acc", Json::num(1.0 - self.0))
            .with_meta("n_samples", Json::num(100.0)))
    }
}

#[test]
fn federated_eval_aggregates_weighted_metrics() {
    let mut job = JobConfig::named("it_fedeval", "stream_test");
    job.clients = three_clients();
    job.min_clients = 3;
    let mut ctl = FederatedEval::new(TensorDict::new());
    let mut f: Box<sim::ExecutorFactory> = Box::new(|i, _s| {
        Ok(Box::new(FixedEval(0.1 * (i + 1) as f64)) as Box<dyn Executor>)
    });
    sim::run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
    assert_eq!(ctl.results.len(), 3);
    assert!((ctl.mean_loss - 0.2).abs() < 1e-9); // equal weights
    assert!((ctl.mean_acc - 0.8).abs() < 1e-9);
}

// ------------------------------------------------- quorum / stragglers

/// A stream_test executor stalling `work_ms` per tensor.
fn stalling_executor(delta: f32, work_ms: u64) -> Box<dyn Executor> {
    let mut e = StreamTestExecutor::new(None, delta);
    e.work_ms = work_ms;
    Box::new(e)
}

#[test]
fn round_finalizes_at_quorum_and_discards_the_straggler() {
    // 3 sampled, quorum 2, 250 ms straggler timeout; site-3 stalls for
    // ~800 ms per task and would shift the mean by +100 if its result
    // were ever folded. Both rounds must finalize with exactly the two
    // fast clients, and site-3's stale round-0 result (arriving during
    // round 1) must be drained and discarded, not folded.
    let mut job = JobConfig::named("it_straggler", "stream_test");
    job.rounds = 2;
    job.clients = three_clients();
    job.min_clients = 2;
    let initial = StreamTestExecutor::build_model(2, 512, 1.0);
    let policy = SamplePolicy {
        min_clients: 2,
        sample_count: 3,
        round_timeout: Some(Duration::from_millis(250)),
    };
    let mut ctl = ScatterAndGather::with_aggregator(
        initial,
        2,
        policy,
        build_aggregator(&AggregatorSpec::Mean),
    );
    ctl.task_name = "stream_test".into();
    let mut f: Box<sim::ExecutorFactory> = Box::new(|i, _s| {
        Ok(if i == 2 {
            stalling_executor(100.0, 400)
        } else {
            Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>
        })
    });
    sim::run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
    assert_eq!(ctl.history.len(), 2);
    for rm in &ctl.history {
        assert_eq!(
            rm.per_client.len(),
            2,
            "round {} should fold exactly the quorum: {:?}",
            rm.round,
            rm.per_client
        );
        assert!(
            rm.per_client.iter().all(|(n, ..)| n != "site-3"),
            "straggler folded in round {}",
            rm.round
        );
    }
    // 2 rounds x 0.5 from the fast clients only
    let v = ctl.model.get("key_000").unwrap().as_f32().unwrap();
    assert!(
        v.iter().all(|&x| (x - 2.0).abs() < 1e-5),
        "stale straggler result leaked into a round: {}",
        v[0]
    );
}

#[test]
fn quorum_gather_tolerates_a_dead_client() {
    use fedflare::coordinator::{
        accept_registration, ClientHandle, Communicator, GatherPolicy, StreamingMean,
    };
    use fedflare::executor::ClientRuntime;
    use fedflare::sfm::inproc;
    use fedflare::streaming::Messenger;

    /// Executor erroring immediately — its client loop dies mid-job.
    struct FailNow;
    impl Executor for FailNow {
        fn execute(&mut self, _t: &FlMessage) -> anyhow::Result<FlMessage> {
            Err(anyhow::anyhow!("injected failure"))
        }
    }

    let mut handles = Vec::new();
    let mut joins = Vec::new();
    for i in 0..3usize {
        let (sa, ca) = inproc::pair(32, &format!("quorum{i}"));
        let mut server_m = Messenger::new(Box::new(sa), 8192, 0);
        let client_m = Messenger::new(Box::new(ca), 8192, (i + 1) as u32);
        let name = format!("site-{}", i + 1);
        joins.push(std::thread::spawn(move || {
            let exec: Box<dyn Executor> = if name == "site-3" {
                Box::new(FailNow)
            } else {
                Box::new(StreamTestExecutor::new(None, 0.5))
            };
            let mut rt = ClientRuntime::new(&name, client_m, exec, vec![]);
            let _ = rt.run_loop(); // site-3 errors out — that's the point
        }));
        let registered = accept_registration(&mut server_m).unwrap();
        handles.push(ClientHandle::spawn(registered, server_m));
    }
    let mut comm = Communicator::new(handles, 7);
    let model = StreamTestExecutor::build_model(2, 256, 1.0);
    let agg: Box<dyn Aggregator> = Box::new(StreamingMean::new(&model));
    let task = FlMessage::task("stream_test", 0, model);
    let mut agg = comm
        .broadcast_and_fold(
            &task,
            &[0, 1, 2],
            agg,
            &[],
            &GatherPolicy { quorum: 2, timeout: None },
            |_r| Ok(()),
        )
        .unwrap();
    assert_eq!(agg.folded(), 2, "exactly the two live clients fold");
    let out = agg.finalize().unwrap();
    assert!((out.get("key_000").unwrap().as_f32().unwrap()[0] - 1.5).abs() < 1e-6);
    // with quorum 3 (all) the same dead client fails the gather
    let model = StreamTestExecutor::build_model(2, 256, 1.0);
    let agg: Box<dyn Aggregator> = Box::new(StreamingMean::new(&model));
    let task = FlMessage::task("stream_test", 1, model);
    let err = comm.broadcast_and_fold(
        &task,
        &[0, 1, 2],
        agg,
        &[],
        &GatherPolicy::all(),
        |_r| Ok(()),
    );
    assert!(err.is_err(), "strict gather must fail on a dead client");
    comm.shutdown();
    drop(comm);
    for j in joins {
        let _ = j.join();
    }
}

// ---------------------------------------------- aggregator strategies

#[test]
fn fedprox_and_fedopt_run_through_the_generic_workflow() {
    // every strategy drives the SAME ScatterAndGather workflow; each has
    // an exact closed-form oracle under the add-delta workload
    let run = |spec: AggregatorSpec| {
        let mut job = JobConfig::named("it_aggs", "stream_test");
        job.rounds = 2;
        job.min_clients = 2;
        let initial = StreamTestExecutor::build_model(2, 128, 1.0);
        let mut ctl = ScatterAndGather::with_aggregator(
            initial,
            2,
            SamplePolicy::strict(2),
            build_aggregator(&spec),
        );
        ctl.task_name = "stream_test".into();
        let mut f: Box<sim::ExecutorFactory> = Box::new(|_i, _s| {
            Ok(Box::new(StreamTestExecutor::new(None, 0.5)) as Box<dyn Executor>)
        });
        sim::run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
        assert_eq!(ctl.history.len(), 2);
        ctl.model.get("key_000").unwrap().as_f32().unwrap()[0] as f64
    };
    // FedAvg: 1 + 2*0.5
    assert!((run(AggregatorSpec::Mean) - 2.0).abs() < 1e-5);
    // FedProx: each round moves d/(1+mu)
    let mu = 1.0;
    let fedprox = run(AggregatorSpec::FedProx { mu });
    assert!((fedprox - (1.0 + 2.0 * 0.5 / (1.0 + mu))).abs() < 1e-5, "{fedprox}");
    // FedOpt-SGD with zero momentum and lr=1 is exactly FedAvg
    let sgd = run(AggregatorSpec::FedOptSgd { lr: 1.0, momentum: 0.0 });
    assert!((sgd - 2.0).abs() < 1e-5, "{sgd}");
    // FedOpt-SGD momentum accumulates: steps 0.5, 0.5+0.25 => 2.25
    let sgdm = run(AggregatorSpec::FedOptSgd { lr: 1.0, momentum: 0.5 });
    assert!((sgdm - 2.25).abs() < 1e-4, "{sgdm}");
    // FedOpt-Adam with a constant pseudo-gradient steps ~lr per round
    let adam = run(AggregatorSpec::FedOptAdam {
        lr: 0.05,
        beta1: 0.9,
        beta2: 0.99,
        eps: 1e-8,
    });
    assert!((adam - 1.1).abs() < 1e-3, "{adam}");
}

#[test]
fn dp_filter_changes_results_secure_agg_does_not() {
    let run = |filters: Vec<FilterSpec>| {
        let mut job = JobConfig::named("it_filters", "stream_test");
        job.rounds = 1;
        job.filters = filters;
        let initial = StreamTestExecutor::build_model(1, 256, 0.0);
        let mut ctl = FedAvg::new(initial, 1, 2);
        ctl.task_name = "stream_test".into();
        let mut f: Box<sim::ExecutorFactory> = Box::new(|_i, _s| {
            Ok(Box::new(StreamTestExecutor::new(None, 1.0)) as Box<dyn Executor>)
        });
        sim::run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
        ctl.model
    };
    let plain = run(vec![]);
    let dp = run(vec![FilterSpec::GaussianDp { clip: 0.5, sigma: 0.1 }]);
    let masked = run(vec![FilterSpec::SecureAgg { seed: 9 }]);
    // DP (tight clip) visibly distorts the aggregate
    assert!(plain.max_abs_diff(&dp) > 0.1);
    // secure-agg masks cancel: aggregate unchanged up to float noise
    assert!(plain.max_abs_diff(&masked) < 1e-4);
}

// ------------------------------------------------------------- with PJRT

#[test]
fn fedavg_trains_nano_gpt_end_to_end() {
    if !have_artifacts() {
        eprintln!("skipping (run `make artifacts`)");
        return;
    }
    let rc = RuntimeClient::start("artifacts").unwrap();
    let mut job = JobConfig::named("it_nano", "gpt_nano");
    job.rounds = 3;
    job.min_clients = 2;
    job.train.local_steps = 4;
    job.train.eval_batches = 1;
    let initial = fedflare::repro::common::initial_model(&job, Some(&rc)).unwrap();
    let mut ctl = FedAvg::new(initial, job.rounds, job.min_clients);
    let job2 = job.clone();
    let rc2 = rc.clone();
    let mut f: Box<sim::ExecutorFactory> = Box::new(move |i, _s| {
        fedflare::repro::common::build_executor(&job2, i, Some(&rc2))
    });
    sim::run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
    assert_eq!(ctl.history.len(), 3);
    let first = ctl.history.first().unwrap().val_loss;
    let last = ctl.history.last().unwrap().val_loss;
    assert!(
        last < first,
        "global val loss should improve: {first} -> {last}"
    );
    // model selection must have picked something
    assert!(ctl.best.is_some());
    assert!(ctl.best_model.is_some());
}

#[test]
fn fedavg_nano_over_tcp_matches_learning() {
    if !have_artifacts() {
        return;
    }
    let rc = RuntimeClient::start("artifacts").unwrap();
    let mut job = JobConfig::named("it_nano_tcp", "gpt_nano");
    job.rounds = 2;
    job.min_clients = 2;
    job.train.local_steps = 2;
    job.train.eval_batches = 1;
    let initial = fedflare::repro::common::initial_model(&job, Some(&rc)).unwrap();
    let mut ctl = FedAvg::new(initial, job.rounds, job.min_clients);
    let job2 = job.clone();
    let rc2 = rc.clone();
    let mut f: Box<sim::ExecutorFactory> = Box::new(move |i, _s| {
        fedflare::repro::common::build_executor(&job2, i, Some(&rc2))
    });
    sim::run_job(&job, DriverKind::Tcp, &mut ctl, &mut f, &results_dir()).unwrap();
    assert_eq!(ctl.history.len(), 2);
    assert!(ctl.history.iter().all(|r| r.val_loss.is_finite()));
}

#[test]
fn peft_job_moves_only_adapter_payload() {
    if !have_artifacts() {
        return;
    }
    let rc = RuntimeClient::start("artifacts").unwrap();
    let mut job = JobConfig::named("it_peft", "gpt_small_lora");
    job.rounds = 1;
    job.min_clients = 2;
    job.trainable_only = true;
    job.train.local_steps = 1;
    job.train.eval_batches = 1;
    let initial = fedflare::repro::common::initial_model(&job, Some(&rc)).unwrap();
    // adapters only: a few hundred KB, not the 3.4 MB full model
    let full = rc.manifest("gpt_small_lora_train").unwrap().param_bytes();
    assert!(initial.byte_size() * 10 < full, "adapter payload too large");
    assert!(initial.names().all(|n| n.contains("lora")));
    let mut ctl = FedAvg::new(initial, 1, 2);
    let job2 = job.clone();
    let rc2 = rc.clone();
    let mut f: Box<sim::ExecutorFactory> = Box::new(move |i, _s| {
        fedflare::repro::common::build_executor(&job2, i, Some(&rc2))
    });
    sim::run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
    assert!(ctl.model.names().all(|n| n.contains("lora")));
}

#[test]
fn checkpoint_roundtrip_through_model_state() {
    if !have_artifacts() {
        return;
    }
    let rc = RuntimeClient::start("artifacts").unwrap();
    let m = rc.manifest("gpt_nano_train").unwrap();
    let mut state = fedflare::model::ModelState::init(&m, 5).unwrap();
    state.step = 42;
    let path = std::env::temp_dir().join("it_ckpt.bin");
    state.save(&path).unwrap();
    let loaded = fedflare::model::ModelState::load(&path).unwrap();
    assert_eq!(loaded.step, 42);
    assert_eq!(loaded.params, state.params);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn broadcast_and_wait_returns_target_order_despite_completion_order() {
    use fedflare::coordinator::{accept_registration, ClientHandle, Communicator};
    use fedflare::executor::ClientRuntime;
    use fedflare::sfm::{inproc, throttle::Throttled, Driver};
    use fedflare::streaming::Messenger;

    // client 0's link is throttled so it completes LAST even though it is
    // dispatched first; the compat wrapper must still hand results back in
    // target order
    let mut handles = Vec::new();
    let mut joins = Vec::new();
    for i in 0..2usize {
        let (sa, ca) = inproc::pair(64, &format!("order{i}"));
        let server_driver: Box<dyn Driver> = if i == 0 {
            Box::new(Throttled::new(sa, 4_000_000, 32 << 10))
        } else {
            Box::new(sa)
        };
        let mut server_m = Messenger::new(server_driver, 32 << 10, 0);
        let client_m = Messenger::new(Box::new(ca), 32 << 10, (i + 1) as u32);
        let name = format!("site-{}", i + 1);
        joins.push(std::thread::spawn(move || {
            let exec = Box::new(StreamTestExecutor::new(None, 1.0));
            ClientRuntime::new(&name, client_m, exec, vec![]).run_loop().unwrap()
        }));
        let registered = accept_registration(&mut server_m).unwrap();
        handles.push(ClientHandle::spawn(registered, server_m));
    }
    let mut comm = Communicator::new(handles, 3);
    let model = StreamTestExecutor::build_model(2, 65_536, 0.0); // 512 kB
    let task = FlMessage::task("stream_test", 0, model);
    let results = comm.broadcast_and_wait(&task, &[0, 1]).unwrap();
    assert_eq!(results.len(), 2);
    assert_eq!(results[0].client, "site-1");
    assert_eq!(results[1].client, "site-2");
    comm.shutdown();
    drop(comm);
    for j in joins {
        assert_eq!(j.join().unwrap(), 1);
    }
}

#[test]
fn throttled_fig5_shape_fast_vs_slow_transfer() {
    // micro Fig-5: slow client's send takes measurably longer
    let mut job = JobConfig::named("it_fig5_shape", "stream_test");
    job.rounds = 1;
    job.stream.chunk_bytes = 64 << 10;
    job.clients = vec![
        ClientSpec {
            name: "fast".into(),
            bandwidth_bps: 0,
            partition: 0,
        },
        ClientSpec {
            name: "slow".into(),
            bandwidth_bps: 3_000_000, // 3 MB/s on a ~4 MB model
            partition: 1,
        },
    ];
    let initial = StreamTestExecutor::build_model(2, 524_288, 1.0);
    let mut ctl = FedAvg::new(initial, 1, 2);
    ctl.task_name = "stream_test".into();
    let t0 = std::time::Instant::now();
    let mut f: Box<sim::ExecutorFactory> =
        Box::new(|_i, _s| Ok(Box::new(StreamTestExecutor::new(None, 0.1)) as Box<dyn Executor>));
    sim::run_job(&job, DriverKind::Tcp, &mut ctl, &mut f, &results_dir()).unwrap();
    let wall = t0.elapsed();
    // 4 MB model, both directions at 3 MB/s => > 2 s; unthrottled would be ms
    assert!(
        wall > std::time::Duration::from_millis(1500),
        "throttling had no effect: {wall:?}"
    );
}
