//! Observability-plane integration tests: a scheduled run must leave a
//! span tree (round → scatter/gather/fold, gather → per-site streams)
//! and the per-site gather histograms in the job's JSONL, and a live
//! tcp deployment must answer `fedflare status` probes mid-round.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use fedflare::config::{ClientSpec, JobConfig};
use fedflare::coordinator::{FedAvg, JobRequest, JobScheduler, JobStatus};
use fedflare::executor::{Executor, StreamTestExecutor};
use fedflare::obs::status::{self, StatusSink, PROBE_SITE};
use fedflare::sfm::accept::{AdmitFn, AuthAcceptor, AuthInfo};
use fedflare::sim::{DriverKind, Fleet};
use fedflare::util::json::Json;

/// The status provider is a process-global slot (last scheduler wins), so
/// tests that assert on provider-sourced fields must not overlap.
static PROVIDER_LOCK: Mutex<()> = Mutex::new(());

fn results_dir(tag: &str) -> String {
    let d = std::env::temp_dir().join(format!("fedflare_obs_tests_{tag}"));
    let _ = std::fs::remove_dir_all(&d);
    let _ = std::fs::create_dir_all(&d);
    d.to_string_lossy().to_string()
}

fn fleet_clients(n: usize) -> Vec<ClientSpec> {
    (0..n)
        .map(|i| ClientSpec {
            name: format!("site-{:02}", i + 1),
            bandwidth_bps: 0,
            partition: i,
        })
        .collect()
}

fn add_delta_job(name: &str, n_clients: usize, rounds: usize) -> JobConfig {
    let mut job = JobConfig::named(name, "stream_test");
    job.rounds = rounds;
    job.clients = fleet_clients(n_clients);
    job.min_clients = n_clients;
    job.stream.chunk_bytes = 4096;
    job
}

fn submit(sched: &JobScheduler, job: JobConfig, work_ms: u64) -> u32 {
    let initial = StreamTestExecutor::build_model(4, 256, 1.0);
    let mut ctl = FedAvg::new(initial, job.rounds, job.min_clients);
    ctl.task_name = "stream_test".into();
    let factory: fedflare::coordinator::OwnedExecutorFactory = Box::new(move |_i, _s| {
        let mut e = StreamTestExecutor::new(None, 0.5);
        e.work_ms = work_ms;
        Ok(Box::new(e) as Box<dyn Executor>)
    });
    sched.submit(JobRequest {
        job,
        controller: Box::new(ctl),
        factory,
    })
}

/// One parsed `span` JSONL event.
#[derive(Debug, Clone)]
struct Span {
    name: String,
    id: u64,
    parent: u64,
    job: u64,
    site: String,
    dur_us: f64,
}

/// Parse a job's `*.events.jsonl`: (spans by id, union of exported histo
/// keys across all `metrics` delta events).
fn parse_events(path: &std::path::Path) -> (HashMap<u64, Span>, Vec<String>) {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    let mut spans = HashMap::new();
    let mut histo_keys = Vec::new();
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let doc = Json::parse(line).unwrap_or_else(|e| panic!("bad JSONL line: {e}\n{line}"));
        match doc.get("kind").as_str() {
            Some("span") => {
                let s = Span {
                    name: doc.get("name").as_str().unwrap_or("").to_string(),
                    id: doc.get("id").as_f64().unwrap_or(0.0) as u64,
                    parent: doc.get("parent").as_f64().unwrap_or(0.0) as u64,
                    job: doc.get("job").as_f64().unwrap_or(0.0) as u64,
                    site: doc.get("site").as_str().unwrap_or("").to_string(),
                    dur_us: doc.get("dur_us").as_f64().unwrap_or(0.0),
                };
                assert!(s.id != 0, "span with zero id: {line}");
                spans.insert(s.id, s);
            }
            Some("metrics") => {
                if let Some(h) = doc.get("histos").as_obj() {
                    for k in h.keys() {
                        if !histo_keys.contains(k) {
                            histo_keys.push(k.clone());
                        }
                    }
                }
            }
            _ => {}
        }
    }
    (spans, histo_keys)
}

#[test]
fn two_job_run_exports_span_trees_and_gather_histograms() {
    let _g = PROVIDER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let dir = results_dir("jsonl");
    let fleet =
        Fleet::connect(&fleet_clients(3), DriverKind::InProc, &Default::default()).unwrap();
    let sched = JobScheduler::new(fleet.clone(), 2, &dir);
    let a = submit(&sched, add_delta_job("obs_a", 3, 3), 0);
    let b = submit(&sched, add_delta_job("obs_b", 3, 2), 0);
    for id in [a, b] {
        let outcome = sched.wait(id);
        assert_eq!(outcome.status, JobStatus::Completed, "{:?}", outcome.error);
    }
    sched.drain();
    fleet.shutdown();

    // the span ring is process-global, so each job's exporter may also
    // drain the other job's spans — the tree structure disambiguates
    let (spans, histo_keys) = parse_events(&std::path::Path::new(&dir).join("obs_a.events.jsonl"));

    // job roots exist and carry the wire-level job id
    let job_roots: Vec<&Span> = spans.values().filter(|s| s.name == "job").collect();
    assert!(!job_roots.is_empty(), "no job spans exported");
    assert!(job_roots.iter().all(|s| s.job != 0));

    // every round span parents a scatter, a gather, and a fold, and the
    // children's summed duration stays within the round's envelope
    let rounds: Vec<&Span> = spans.values().filter(|s| s.name == "round").collect();
    assert!(!rounds.is_empty(), "no round spans exported");
    let mut full_rounds = 0;
    for r in &rounds {
        assert!(r.job != 0, "round span missing its job id");
        let kids: Vec<&Span> = spans.values().filter(|s| s.parent == r.id).collect();
        let has = |n: &str| kids.iter().any(|s| s.name == n);
        if has("scatter") && has("gather") && has("fold") {
            full_rounds += 1;
            let child_sum: f64 = kids.iter().map(|s| s.dur_us).sum();
            assert!(
                child_sum <= r.dur_us * 1.2,
                "children ({child_sum} µs) overflow their round ({} µs)",
                r.dur_us
            );
        }
    }
    assert!(
        full_rounds > 0,
        "no round span parents scatter+gather+fold: {rounds:?}"
    );

    // per-site gather streams hang off a gather span and name their site
    let gather_sites: Vec<&Span> = spans
        .values()
        .filter(|s| s.name == "gather.site")
        .collect();
    assert!(!gather_sites.is_empty(), "no gather.site spans exported");
    for gs in &gather_sites {
        assert!(!gs.site.is_empty(), "gather.site span without a site");
        let parent = spans
            .get(&gs.parent)
            .unwrap_or_else(|| panic!("gather.site {gs:?} has a dangling parent"));
        assert_eq!(parent.name, "gather", "gather.site parent is {parent:?}");
    }

    // client train spans made it across threads with their site label
    assert!(
        spans
            .values()
            .any(|s| s.name == "train" && !s.site.is_empty()),
        "no train spans exported"
    );

    // the per-site gather histogram family landed in a metrics delta
    assert!(
        histo_keys
            .iter()
            .any(|k| k.starts_with("gather.site_ms{site=")),
        "no gather.site_ms histograms exported; saw {histo_keys:?}"
    );
}

#[test]
fn status_query_answers_mid_round_over_tcp() {
    let _g = PROVIDER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let dir = results_dir("status");
    let fleet =
        Fleet::connect(&fleet_clients(4), DriverKind::Tcp, &Default::default()).unwrap();
    // `with_store`/`new` installs the scheduler's status provider
    let sched = JobScheduler::new(fleet.clone(), 1, &dir);

    // the status endpoint: probe connections authenticate like sites and
    // are answered by a StatusSink (same wiring as `serve --status-port`)
    let listener = fedflare::sfm::tcp::bind("127.0.0.1:0").unwrap();
    let admit: AdmitFn = Arc::new(|_info: AuthInfo, send_stream, _tok| {
        StatusSink::new(send_stream)
            .map(|s| Box::new(s) as _)
            .map_err(|e| format!("status probe: {e}"))
    });
    let acceptor =
        AuthAcceptor::spawn(listener, true, Duration::from_secs(5), admit).unwrap();
    let addr = acceptor.local_addr().to_string();

    // a job slow enough (5 rounds x ~400 ms of simulated compute) that
    // the probe below lands mid-round
    let id = submit(&sched, add_delta_job("obs_status", 4, 5), 100);
    let t0 = Instant::now();
    let mut doc;
    loop {
        doc = status::query(&addr, PROBE_SITE, "", Duration::from_secs(5)).unwrap();
        let running = doc
            .get("jobs")
            .get(&id.to_string())
            .get("status")
            .as_str()
            == Some("running");
        if running || t0.elapsed() > Duration::from_secs(10) {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }

    assert_eq!(doc.get("v").as_f64(), Some(1.0));
    // the probe saw our job mid-flight, by id and name
    let job = doc.get("jobs").get(&id.to_string());
    assert_eq!(job.get("name").as_str(), Some("obs_status"));
    assert_eq!(job.get("status").as_str(), Some("running"));
    // per-site fleet state from the registry snapshot
    let sites = doc.get("sites").as_obj().expect("sites object");
    assert_eq!(sites.len(), 4, "sites: {sites:?}");
    for (name, state) in sites {
        assert!(name.starts_with("site-"));
        assert_eq!(state.as_str(), Some("live"), "site {name}: {state:?}");
    }
    // per-shard reactor load: the tcp fleet's connections are parked on
    // the global reactor, so the shard table must show them
    let shards = doc.get("shards").as_arr().expect("shards array");
    assert!(!shards.is_empty());
    let conns: f64 = shards
        .iter()
        .map(|s| s.get("conns").as_f64().unwrap_or(0.0))
        .sum();
    assert!(conns >= 4.0, "expected >= 4 reactor connections, saw {conns}");
    // the metrics snapshot rides along
    assert!(doc.get("metrics").get("counters").as_obj().is_some());

    let outcome = sched.wait(id);
    assert_eq!(outcome.status, JobStatus::Completed, "{:?}", outcome.error);
    acceptor.shutdown();
    sched.drain();
    fleet.shutdown();
}
