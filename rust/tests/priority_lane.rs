//! Priority-lane regression test: a client in the middle of a large,
//! bandwidth-throttled transfer must keep heartbeating — the liveness
//! signal rides the mux's control lane, bypasses the token bucket, and
//! is timestamped the moment it arrives, so the fleet's deadline sweep
//! never marks a busy-but-healthy site Suspect. Exercised over both the
//! inproc and TCP drivers, mirroring how [`sim::Fleet`] and the real
//! `fedflare server` feed [`fleet::Registry`] from
//! [`MuxConn::last_heartbeat`].

use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use fedflare::fleet::{ClientState, Registry};
use fedflare::sfm::inproc;
use fedflare::sfm::mux::MuxConn;
use fedflare::sfm::tcp::{self, TcpDriver};
use fedflare::sfm::{Driver, Frame, FLAG_FIRST, FLAG_LAST};

/// Client-side send cap: slow enough that the payload takes over a
/// second on the wire, fast enough to keep the test snappy.
const RATE_BPS: u64 = 512 * 1024;
const BURST_BYTES: u64 = 32 * 1024;
const PAYLOAD: usize = 768 * 1024;
const CHUNK: usize = 16 * 1024;

const HEARTBEAT: Duration = Duration::from_millis(50);
const SUSPECT_AFTER: Duration = Duration::from_millis(400);

fn wait_until(timeout: Duration, mut f: impl FnMut() -> bool) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < timeout {
        if f() {
            return true;
        }
        thread::sleep(Duration::from_millis(5));
    }
    f()
}

/// Chunk `payload` into a single multi-frame stream (the job id is
/// stamped by the [`MuxHandle`](fedflare::sfm::mux::MuxHandle) on send).
fn chunk_frames(stream: u32, payload: &[u8], chunk: usize) -> Vec<Frame> {
    let total = payload.len().div_ceil(chunk).max(1) as u32;
    payload
        .chunks(chunk)
        .enumerate()
        .map(|(i, part)| {
            let mut flags = 0u8;
            if i == 0 {
                flags |= FLAG_FIRST;
            }
            if i as u32 == total - 1 {
                flags |= FLAG_LAST;
            }
            Frame {
                flags,
                kind: 0,
                job: 0,
                stream,
                seq: i as u32,
                total,
                payload: part.to_vec().into(),
            }
        })
        .collect()
}

/// A connected (server mux, client mux) pair over inproc channels, the
/// client's sends throttled to [`RATE_BPS`].
fn inproc_pair() -> (MuxConn, MuxConn) {
    let (s, c) = inproc::pair(64, "lane");
    let (sr, cr) = (s.recv_half(), c.recv_half());
    let server = MuxConn::spawn(Box::new(s), Box::new(sr), 0, BURST_BYTES);
    let client = MuxConn::spawn(Box::new(c), Box::new(cr), RATE_BPS, BURST_BYTES);
    (server, client)
}

/// Same shape over a real TCP loopback connection.
fn tcp_pair() -> (MuxConn, MuxConn) {
    let listener = tcp::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr");
    let cd = TcpDriver::connect(addr, false).expect("connect");
    let cdr = cd.try_clone().expect("clone client driver");
    let client = MuxConn::spawn(Box::new(cd), Box::new(cdr), RATE_BPS, BURST_BYTES);
    let (conn, _) = listener.accept().expect("accept");
    let sd = TcpDriver::from_stream(conn, false).expect("wrap accepted");
    let sdr = sd.try_clone().expect("clone server driver");
    let server = MuxConn::spawn(Box::new(sd), Box::new(sdr), 0, BURST_BYTES);
    (server, client)
}

/// The scenario both drivers run: heartbeats flow, a throttled multi-MB
/// transfer saturates the link for over a second, and the registry —
/// swept on a deadline tighter than the transfer — never demotes the
/// client, because heartbeats keep arriving through the priority lane.
fn heartbeats_outrun_a_saturated_link(server: MuxConn, client: MuxConn, tag: &str) {
    let registry = Arc::new(Registry::new());
    let idx = registry.join(tag);
    registry.connected(idx);
    client.enable_heartbeat(HEARTBEAT);
    assert!(
        wait_until(Duration::from_secs(5), || server.last_heartbeat().is_some()),
        "[{tag}] first heartbeat never arrived"
    );

    // saturate the link: a payload that takes ~1.5s at the send cap,
    // streamed from a worker thread while the test thread plays the
    // fleet's liveness sweep
    let mut tx = client.handle(1);
    let payload = vec![0xA5u8; PAYLOAD];
    let t0 = Instant::now();
    let sender = thread::spawn(move || {
        for frame in chunk_frames(7, &payload, CHUNK) {
            tx.send(frame).expect("throttled send");
        }
    });
    let mut rx = server.handle(1);
    let drain = thread::spawn(move || {
        let mut got = 0usize;
        while got < PAYLOAD {
            got += rx.recv().expect("drain transfer").payload.len();
        }
        got
    });

    // while the transfer is in flight: observe heartbeats exactly the
    // way the server's sweep task does (last_heartbeat -> heard ->
    // sweep) and demand the client stays eligible throughout
    let mut max_staleness = Duration::ZERO;
    let deadline = Instant::now() + Duration::from_secs(30);
    while !(sender.is_finished() && drain.is_finished()) {
        assert!(Instant::now() < deadline, "[{tag}] transfer wedged");
        if let Some(at) = server.last_heartbeat() {
            max_staleness = max_staleness.max(at.elapsed());
            registry.heard(idx, at);
        }
        registry.sweep(SUSPECT_AFTER, Duration::from_secs(60));
        assert_eq!(
            registry.state_of(tag),
            Some(ClientState::Live),
            "[{tag}] client demoted mid-transfer after {:?}",
            t0.elapsed()
        );
        thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(drain.join().unwrap(), PAYLOAD, "[{tag}] payload truncated");
    sender.join().unwrap();

    // the throttle really applied — the transfer overlapped many
    // heartbeat intervals, so the assertions above had teeth
    let took = t0.elapsed();
    assert!(
        took >= Duration::from_millis(500),
        "[{tag}] transfer finished in {took:?}; too fast to exercise the lane"
    );
    assert!(
        max_staleness < SUSPECT_AFTER,
        "[{tag}] heartbeat gap {max_staleness:?} crossed the suspect deadline"
    );
}

#[test]
fn heartbeats_survive_large_transfer_inproc() {
    let (server, client) = inproc_pair();
    heartbeats_outrun_a_saturated_link(server, client, "site-inproc");
}

#[test]
fn heartbeats_survive_large_transfer_tcp() {
    let (server, client) = tcp_pair();
    heartbeats_outrun_a_saturated_link(server, client, "site-tcp");
}
