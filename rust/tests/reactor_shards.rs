//! Sharded-reactor property tests: pinning, ordering, balance, liveness
//! under load, kill/rejoin, and the event-driven accept path — all with
//! the shard count forced to a multi-shard configuration (so a 1-core CI
//! box still exercises cross-shard behavior). The same suite must also
//! pass with `FEDFLARE_REACTOR_SHARDS=1`, where every multi-shard
//! assertion gates itself off and the remaining checks pin the
//! single-shard (pre-sharding) semantics.

use std::io::Write as _;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use fedflare::fleet::{ClientState, Registry};
use fedflare::sfm::accept::{AuthAcceptor, AuthInfo};
use fedflare::sfm::inproc;
use fedflare::sfm::mux::MuxConn;
use fedflare::sfm::reactor::{self, FrameSink, SinkStatus};
use fedflare::sfm::{Frame, SfmError, FLAG_FIRST, FLAG_LAST, KIND_AUTH};
use fedflare::util::bytes::Writer;

/// Force a multi-shard reactor before its first use unless the caller
/// (CI's shard-count sweep) pinned a count explicitly.
fn force_shards() {
    if std::env::var_os("FEDFLARE_REACTOR_SHARDS").is_none() {
        std::env::set_var("FEDFLARE_REACTOR_SHARDS", "4");
    }
}

fn wait_until(timeout: Duration, mut f: impl FnMut() -> bool) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < timeout {
        if f() {
            return true;
        }
        thread::sleep(Duration::from_millis(5));
    }
    f()
}

/// A connected (server mux, client mux) inproc pair; `rate_bps = 0`
/// means unthrottled.
fn mux_pair(tag: &str, rate_bps: u64) -> (MuxConn, MuxConn) {
    let (s, c) = inproc::pair(64, tag);
    let (sr, cr) = (s.recv_half(), c.recv_half());
    let server = MuxConn::spawn(Box::new(s), Box::new(sr), 0, 32 * 1024);
    let client = MuxConn::spawn(Box::new(c), Box::new(cr), rate_bps, 32 * 1024);
    (server, client)
}

/// One single-frame message carrying a u32 counter.
fn counter_frame(stream: u32, i: u32) -> Frame {
    Frame {
        flags: FLAG_FIRST | FLAG_LAST,
        kind: 0,
        job: 0,
        stream,
        seq: 0,
        total: 1,
        payload: i.to_le_bytes().to_vec().into(),
    }
}

/// Frames on one connection must arrive in send order no matter how the
/// connection pool spreads over shards — a connection lives on exactly
/// one shard, so there is no cross-thread reordering to defend against.
/// Also checks the pinning balance: with shards > 1 every shard carries
/// load and no shard holds more than 2x another's connections.
#[test]
fn frames_stay_ordered_and_connections_balance_across_shards() {
    force_shards();
    const PAIRS: usize = 32;
    const FRAMES: u32 = 200;
    let pairs: Vec<(MuxConn, MuxConn)> =
        (0..PAIRS).map(|i| mux_pair(&format!("ord-{i}"), 0)).collect();

    // balance: 2 registered receive paths per pair, least-loaded pinned
    let stats = reactor::global().shard_stats();
    let conns: Vec<usize> = stats.iter().map(|s| s.conns).collect();
    let total: usize = conns.iter().sum();
    assert!(
        total >= 2 * PAIRS,
        "expected at least {} registered conns, shards report {conns:?}",
        2 * PAIRS
    );
    if reactor::global().shard_count() > 1 {
        let loaded: Vec<usize> = conns.iter().copied().filter(|&c| c > 0).collect();
        assert!(
            loaded.len() == conns.len(),
            "idle shard with {} conns to place: {conns:?}",
            total
        );
        let (max, min) = (
            *loaded.iter().max().unwrap(),
            *loaded.iter().min().unwrap(),
        );
        // +4 of additive slack: other tests in this binary register and
        // drop their own connections concurrently with the snapshot
        assert!(
            max <= 2 * min + 4,
            "shard imbalance beyond 2x: {conns:?}"
        );
    }

    // ordering: every connection ships its counters concurrently; each
    // receiver must observe a strictly increasing sequence
    let senders: Vec<_> = pairs
        .iter()
        .map(|(_, client)| {
            let mut tx = client.handle(1);
            thread::spawn(move || {
                for i in 0..FRAMES {
                    tx.send(counter_frame(7, i)).expect("send counter");
                }
            })
        })
        .collect();
    let receivers: Vec<_> = pairs
        .iter()
        .map(|(server, _)| {
            let mut rx = server.handle(1);
            thread::spawn(move || {
                for want in 0..FRAMES {
                    let f = rx.recv().expect("recv counter");
                    let got = u32::from_le_bytes(f.payload[..4].try_into().unwrap());
                    assert_eq!(got, want, "frame reordered on one connection");
                }
            })
        })
        .collect();
    for h in senders {
        h.join().unwrap();
    }
    for h in receivers {
        h.join().unwrap();
    }
}

/// The priority-lane guarantee holds verbatim under sharding: a client
/// mid-saturating-transfer keeps heartbeating, and the registry sweep
/// never demotes it. (With shards forced to 1 this re-pins the
/// pre-sharding behavior byte-for-byte.)
#[test]
fn heartbeats_survive_saturating_transfer_with_shards() {
    force_shards();
    const RATE_BPS: u64 = 512 * 1024;
    const PAYLOAD: usize = 768 * 1024;
    const CHUNK: usize = 16 * 1024;
    const HEARTBEAT: Duration = Duration::from_millis(50);
    const SUSPECT_AFTER: Duration = Duration::from_millis(400);

    let (server, client) = mux_pair("lane-sharded", RATE_BPS);
    let registry = Arc::new(Registry::new());
    let idx = registry.join("lane-sharded");
    registry.connected(idx);
    client.enable_heartbeat(HEARTBEAT);
    assert!(
        wait_until(Duration::from_secs(5), || server.last_heartbeat().is_some()),
        "first heartbeat never arrived"
    );

    let mut tx = client.handle(1);
    let payload = vec![0xA5u8; PAYLOAD];
    let total = PAYLOAD.div_ceil(CHUNK) as u32;
    let sender = thread::spawn(move || {
        for (i, part) in payload.chunks(CHUNK).enumerate() {
            let mut flags = 0u8;
            if i == 0 {
                flags |= FLAG_FIRST;
            }
            if i as u32 == total - 1 {
                flags |= FLAG_LAST;
            }
            tx.send(Frame {
                flags,
                kind: 0,
                job: 0,
                stream: 9,
                seq: i as u32,
                total,
                payload: part.to_vec().into(),
            })
            .expect("throttled send");
        }
    });
    let mut rx = server.handle(1);
    let drain = thread::spawn(move || {
        let mut got = 0usize;
        while got < PAYLOAD {
            got += rx.recv().expect("drain transfer").payload.len();
        }
        got
    });

    let mut max_staleness = Duration::ZERO;
    let deadline = Instant::now() + Duration::from_secs(30);
    while !(sender.is_finished() && drain.is_finished()) {
        assert!(Instant::now() < deadline, "transfer wedged");
        if let Some(at) = server.last_heartbeat() {
            max_staleness = max_staleness.max(at.elapsed());
            registry.heard(idx, at);
        }
        registry.sweep(SUSPECT_AFTER, Duration::from_secs(60));
        assert_eq!(
            registry.state_of("lane-sharded"),
            Some(ClientState::Live),
            "client demoted mid-transfer"
        );
        thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(drain.join().unwrap(), PAYLOAD, "payload truncated");
    sender.join().unwrap();
    assert!(
        max_staleness < SUSPECT_AFTER,
        "heartbeat gap {max_staleness:?} crossed the suspect deadline"
    );
}

/// Fleet kill/rejoin semantics are shard-count independent: a killed
/// client goes Suspect via the dead-transport observation, and a fresh
/// connection brings it back to Live with new heartbeat evidence.
#[test]
fn kill_and_rejoin_pass_under_forced_shards() {
    force_shards();
    const HEARTBEAT: Duration = Duration::from_millis(50);
    const SUSPECT_AFTER: Duration = Duration::from_millis(400);
    let registry = Arc::new(Registry::new());

    let observe = |server: &MuxConn, idx: usize| {
        if server.is_dead() {
            registry.suspect(idx);
        } else if let Some(at) = server.last_heartbeat() {
            registry.heard(idx, at);
        }
        registry.sweep(SUSPECT_AFTER, Duration::from_secs(60));
    };

    let (server, client) = mux_pair("rejoin-0", 0);
    client.enable_heartbeat(HEARTBEAT);
    let idx = registry.join("rejoin-0");
    registry.connected(idx);
    assert!(
        wait_until(Duration::from_secs(5), || {
            observe(&server, idx);
            registry.state_of("rejoin-0") == Some(ClientState::Live)
                && server.last_heartbeat().is_some()
        }),
        "client never went Live"
    );

    client.kill();
    assert!(
        wait_until(Duration::from_secs(5), || {
            observe(&server, idx);
            registry.state_of("rejoin-0") == Some(ClientState::Suspect)
        }),
        "kill never observed as Suspect"
    );
    server.kill();

    // the rejoin: a brand-new connection (fresh shard pinning) for the
    // same site name, promoted on fresh heartbeat evidence
    let (server2, client2) = mux_pair("rejoin-0", 0);
    client2.enable_heartbeat(HEARTBEAT);
    let idx2 = registry.join("rejoin-0");
    registry.connected(idx2);
    assert!(
        wait_until(Duration::from_secs(5), || {
            observe(&server2, idx2);
            registry.state_of("rejoin-0") == Some(ClientState::Live)
                && server2.last_heartbeat().is_some()
        }),
        "rejoin never observed as Live with heartbeat evidence"
    );
}

/// The length-prefixed wire bytes of one auth handshake frame.
fn auth_wire(name: &str, token: &str) -> Vec<u8> {
    let mut w = Writer::new();
    w.str(name);
    w.str(token);
    let f = Frame {
        flags: FLAG_FIRST | FLAG_LAST,
        kind: KIND_AUTH,
        job: 0,
        stream: 0,
        seq: 0,
        total: 1,
        payload: w.into_vec().into(),
    };
    let bytes = f.encode();
    let mut wire = (bytes.len() as u32).to_le_bytes().to_vec();
    wire.extend_from_slice(&bytes);
    wire
}

struct CountSink;
impl FrameSink for CountSink {
    fn on_frame(&mut self, _f: Frame) -> SinkStatus {
        SinkStatus::Ready
    }
    fn on_resume(&mut self) -> SinkStatus {
        SinkStatus::Ready
    }
    fn on_closed(&mut self, _e: SfmError) {}
}

/// An accept storm against the event-driven gate: many clients auth at
/// once and all are admitted, while one silent dialer is reaped by the
/// timer-wheel deadline instead of wedging anything.
#[test]
fn accept_storm_admits_herd_and_reaps_silent_dialer() {
    force_shards();
    const HERD: usize = 50;
    let admitted = Arc::new(std::sync::Mutex::new(Vec::<String>::new()));
    let rejected = Arc::new(AtomicBool::new(false));
    let adm = admitted.clone();
    let rej = rejected.clone();
    let acceptor = AuthAcceptor::spawn(
        fedflare::sfm::tcp::bind("127.0.0.1:0").unwrap(),
        true,
        Duration::from_millis(500),
        Arc::new(move |info: AuthInfo, _send, _tok| {
            if info.token != "letmein" {
                rej.store(true, Ordering::SeqCst);
                return Err("bad token".into());
            }
            adm.lock().unwrap().push(info.name);
            Ok(Box::new(CountSink) as Box<dyn FrameSink>)
        }),
    )
    .unwrap();
    let addr = acceptor.local_addr();

    let mut silent = std::net::TcpStream::connect(addr).unwrap();
    let dialers: Vec<_> = (0..HERD)
        .map(|i| {
            thread::spawn(move || {
                let mut s = std::net::TcpStream::connect(addr).unwrap();
                s.write_all(&auth_wire(&format!("site-{i:02}"), "letmein"))
                    .unwrap();
                s
            })
        })
        .collect();
    let streams: Vec<_> = dialers.into_iter().map(|h| h.join().unwrap()).collect();

    assert!(
        wait_until(Duration::from_secs(10), || admitted.lock().unwrap().len() == HERD),
        "only {}/{HERD} admitted",
        admitted.lock().unwrap().len()
    );
    assert!(!rejected.load(Ordering::SeqCst), "a valid dialer was rejected");
    let mut names = admitted.lock().unwrap().clone();
    names.sort();
    names.dedup();
    assert_eq!(names.len(), HERD, "duplicate admissions");

    // the silent dialer is dropped at the deadline — observed as EOF.
    // A read timeout here would mean the gate never reaped it: the
    // deadline is 500 ms, so 5 s of patience distinguishes the two.
    silent
        .set_read_timeout(Some(Duration::from_secs(5)))
        .unwrap();
    let mut buf = [0u8; 1];
    let n = std::io::Read::read(&mut silent, &mut buf)
        .expect("silent dialer not reaped: read timed out instead of EOF");
    assert_eq!(n, 0, "silent dialer was not reaped by the deadline");

    drop(streams);
    acceptor.shutdown();
}
