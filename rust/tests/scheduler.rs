//! Session-layer / scheduler integration tests: many FL jobs multiplexed
//! concurrently over ONE shared client fleet must behave exactly like
//! the same jobs run sequentially — per-job results byte-identical, an
//! aborted job's streams drained while survivors finish clean, and
//! genuine wall-clock overlap from the concurrency.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use fedflare::config::{ClientSpec, JobConfig};
use fedflare::coordinator::{
    Communicator, Controller, FedAvg, JobRequest, JobScheduler, JobStatus, ServerCtx,
};
use fedflare::executor::{Executor, StreamTestExecutor};
use fedflare::sim::{DriverKind, Fleet};

fn results_dir() -> String {
    let d = std::env::temp_dir().join("fedflare_scheduler_tests");
    let _ = std::fs::create_dir_all(&d);
    d.to_string_lossy().to_string()
}

fn fleet_clients(n: usize) -> Vec<ClientSpec> {
    (0..n)
        .map(|i| ClientSpec {
            name: format!("site-{:02}", i + 1),
            bandwidth_bps: 0,
            partition: i,
        })
        .collect()
}

/// The add-delta job: `n_clients` of the fleet, `rounds` rounds, every
/// client adding `delta` (all-equal deltas make the streaming mean
/// bit-exact regardless of fold order — the oracle-equality hook).
fn add_delta_job(name: &str, n_clients: usize, rounds: usize) -> JobConfig {
    let mut job = JobConfig::named(name, "stream_test");
    job.rounds = rounds;
    job.clients = fleet_clients(n_clients);
    job.min_clients = n_clients;
    job.stream.chunk_bytes = 4096;
    job
}

/// What one finished job reports for comparison: the final model bytes
/// plus a per-round (round, per-client name/weight) summary.
type JobSummary = (Vec<u8>, Vec<(usize, Vec<(String, f64)>)>);
type SharedSummary = Arc<Mutex<Option<JobSummary>>>;

/// Controller wrapper capturing the inner workflow's outcome into a
/// shared cell (scheduled controllers are moved into job threads, so
/// results must come out through a side channel).
struct Reporting {
    inner: FedAvg,
    out: SharedSummary,
}

impl Controller for Reporting {
    fn name(&self) -> &'static str {
        "reporting"
    }
    fn run(&mut self, comm: &mut Communicator, ctx: &mut ServerCtx) -> anyhow::Result<()> {
        let result = self.inner.run(comm, ctx);
        let hist = self
            .inner
            .history
            .iter()
            .map(|h| {
                (
                    h.round,
                    h.per_client
                        .iter()
                        .map(|(n, _, _, w)| (n.clone(), *w))
                        .collect::<Vec<_>>(),
                )
            })
            .collect();
        *self.out.lock().unwrap() = Some((self.inner.model.to_bytes(), hist));
        result
    }
}

/// Submit one add-delta job (keys x elems model, per-client `delta`,
/// `work_ms` of simulated compute per tensor) and hand back the id and
/// the shared summary cell.
fn submit_job(
    sched: &JobScheduler,
    job: JobConfig,
    keys: usize,
    elems: usize,
    delta: f32,
    work_ms: u64,
) -> (u32, SharedSummary) {
    let initial = StreamTestExecutor::build_model(keys, elems, 1.0);
    let mut ctl = FedAvg::new(initial, job.rounds, job.min_clients);
    ctl.task_name = "stream_test".into();
    let out: SharedSummary = Arc::new(Mutex::new(None));
    let reporting = Reporting {
        inner: ctl,
        out: out.clone(),
    };
    let factory: fedflare::coordinator::OwnedExecutorFactory = Box::new(move |_i, _s| {
        let mut e = StreamTestExecutor::new(None, delta);
        e.work_ms = work_ms;
        Ok(Box::new(e) as Box<dyn Executor>)
    });
    let id = sched.submit(JobRequest {
        job,
        controller: Box::new(reporting),
        factory,
    });
    (id, out)
}

/// Run the same 4 jobs over one shared fleet at `max_concurrent`,
/// returning each job's summary by job name.
fn run_batch(kind: DriverKind, max_concurrent: usize, tag: &str) -> Vec<(String, JobSummary)> {
    let fleet = Fleet::connect(&fleet_clients(3), kind, &Default::default()).unwrap();
    let sched = JobScheduler::new(fleet.clone(), max_concurrent, &results_dir());
    let deltas = [0.25f32, 0.5, 1.0, 2.0];
    let mut submitted = Vec::new();
    for (j, delta) in deltas.iter().enumerate() {
        let name = format!("sched_{tag}_{j}");
        let job = add_delta_job(&name, 3, 3);
        let (id, out) = submit_job(&sched, job, 3, 600, *delta, 0);
        submitted.push((name, id, out, *delta));
    }
    let mut results = Vec::new();
    for (name, id, out, delta) in submitted {
        let outcome = sched.wait(id);
        assert_eq!(
            outcome.status,
            JobStatus::Completed,
            "job '{name}': {:?}",
            outcome.error
        );
        let summary = out.lock().unwrap().take().expect("summary reported");
        // sanity: the job's own oracle (initial 1.0 + rounds * delta)
        let model = fedflare::tensor::TensorDict::from_bytes(&summary.0).unwrap();
        let v = model.get("key_000").unwrap().as_f32().unwrap();
        let oracle = 1.0 + 3.0 * delta;
        assert!(
            v.iter().all(|&x| (x - oracle).abs() < 1e-5),
            "job '{name}': expected {oracle}, got {}",
            v[0]
        );
        results.push((name, summary));
    }
    sched.drain();
    fleet.shutdown();
    results
}

/// The acceptance oracle: N=4 concurrent jobs over one shared fleet
/// produce per-job histories and models **byte-identical** to the same
/// jobs run sequentially over the same kind of fleet.
fn concurrent_matches_sequential(kind: DriverKind, tag: &str) {
    let concurrent = run_batch(kind, 4, &format!("{tag}_con"));
    let sequential = run_batch(kind, 1, &format!("{tag}_seq"));
    assert_eq!(concurrent.len(), sequential.len());
    for ((cn, cs), (sn, ss)) in concurrent.iter().zip(sequential.iter()) {
        // names differ only by the batch tag; order is submission order
        assert_eq!(cn.replace("_con_", "_"), sn.replace("_seq_", "_"));
        assert_eq!(cs.0, ss.0, "job {cn}: model bytes diverged");
        assert_eq!(cs.1, ss.1, "job {cn}: history diverged");
    }
}

#[test]
fn four_concurrent_jobs_match_sequential_oracle_inproc() {
    concurrent_matches_sequential(DriverKind::InProc, "ip");
}

#[test]
fn four_concurrent_jobs_match_sequential_oracle_tcp() {
    concurrent_matches_sequential(DriverKind::Tcp, "tcp");
}

#[test]
fn concurrent_jobs_overlap_in_wall_clock() {
    // 4 jobs x 2 rounds x (2 keys x 30 ms) of simulated compute: run
    // sequentially that is ~8 x 120 ms of compute; run concurrently the
    // jobs overlap on the shared fleet. Demand a conservative 25% win so
    // loaded CI machines don't flake, and print the ratio for the bench.
    let run = |max_concurrent: usize, tag: &str| {
        let fleet =
            Fleet::connect(&fleet_clients(2), DriverKind::InProc, &Default::default()).unwrap();
        let sched = JobScheduler::new(fleet.clone(), max_concurrent, &results_dir());
        let t0 = Instant::now();
        let mut ids = Vec::new();
        for j in 0..4 {
            let name = format!("sched_overlap_{tag}_{j}");
            let job = add_delta_job(&name, 2, 2);
            let (id, _out) = submit_job(&sched, job, 2, 64, 0.5, 30);
            ids.push(id);
        }
        for id in ids {
            assert_eq!(sched.wait(id).status, JobStatus::Completed);
        }
        sched.drain();
        fleet.shutdown();
        t0.elapsed()
    };
    let sequential = run(1, "seq");
    let concurrent = run(4, "con");
    println!("sequential {sequential:?} vs concurrent {concurrent:?}");
    assert!(
        concurrent < sequential.mul_f64(0.75),
        "no concurrency win: sequential {sequential:?} vs concurrent {concurrent:?}"
    );
}

#[test]
fn abort_mid_round_drains_and_survivors_finish_clean() {
    let fleet =
        Fleet::connect(&fleet_clients(3), DriverKind::InProc, &Default::default()).unwrap();
    let sched = JobScheduler::new(fleet.clone(), 3, &results_dir());
    // the victim: long job (5 rounds x 2 keys x 100 ms per client)
    let (victim, _vout) = submit_job(
        &sched,
        add_delta_job("sched_abort_victim", 3, 5),
        2,
        256,
        100.0,
        100,
    );
    // two survivors overlapping the abort window
    let (s1, out1) = submit_job(&sched, add_delta_job("sched_abort_s1", 3, 5), 2, 256, 0.5, 40);
    let (s2, out2) = submit_job(&sched, add_delta_job("sched_abort_s2", 3, 5), 2, 256, 1.0, 40);
    std::thread::sleep(Duration::from_millis(300));
    assert_eq!(sched.status(victim), Some(JobStatus::Running));
    sched.abort(victim);
    let aborted = sched.wait(victim);
    assert_eq!(aborted.status, JobStatus::Aborted, "{:?}", aborted.error);
    // survivors complete with their exact oracles, untouched by the abort
    for (id, out, delta) in [(s1, out1, 0.5f32), (s2, out2, 1.0f32)] {
        let outcome = sched.wait(id);
        assert_eq!(outcome.status, JobStatus::Completed, "{:?}", outcome.error);
        let (model_bytes, hist) = out.lock().unwrap().take().unwrap();
        let model = fedflare::tensor::TensorDict::from_bytes(&model_bytes).unwrap();
        let v = model.get("key_000").unwrap().as_f32().unwrap();
        let oracle = 1.0 + 5.0 * delta;
        assert!(
            v.iter().all(|&x| (x - oracle).abs() < 1e-5),
            "survivor diverged: expected {oracle}, got {}",
            v[0]
        );
        assert_eq!(hist.len(), 5);
    }
    // the fleet is healthy after the abort: a fresh job over the same
    // connections completes — the aborted job's channels were drained,
    // not wedged
    let fresh_job = add_delta_job("sched_abort_fresh", 3, 2);
    let (fresh, fout) = submit_job(&sched, fresh_job, 2, 64, 0.25, 0);
    let outcome = sched.wait(fresh);
    assert_eq!(outcome.status, JobStatus::Completed, "{:?}", outcome.error);
    assert!(fout.lock().unwrap().is_some());
    sched.drain();
    fleet.shutdown();
}

#[test]
fn abort_of_a_queued_job_never_runs_it() {
    let fleet =
        Fleet::connect(&fleet_clients(2), DriverKind::InProc, &Default::default()).unwrap();
    let sched = JobScheduler::new(fleet.clone(), 1, &results_dir());
    // slow job occupies the single slot
    let (running, _r) = submit_job(&sched, add_delta_job("sched_q_run", 2, 3), 2, 64, 0.5, 60);
    let (queued, qout) = submit_job(&sched, add_delta_job("sched_q_wait", 2, 3), 2, 64, 0.5, 0);
    assert_eq!(sched.status(queued), Some(JobStatus::Queued));
    sched.abort(queued);
    let out = sched.wait(queued);
    assert_eq!(out.status, JobStatus::Aborted);
    assert!(out.controller.is_some(), "queued controller handed back");
    assert!(qout.lock().unwrap().is_none(), "aborted-in-queue job never ran");
    assert_eq!(sched.wait(running).status, JobStatus::Completed);
    sched.drain();
    fleet.shutdown();
}

#[test]
fn tree_job_composes_with_flat_jobs_on_one_fleet() {
    // 9-client fleet: a hierarchical job (branching 3 -> 3 mid-tier
    // nodes) runs concurrently with a flat job over a 3-client subset;
    // both hit their oracles — mid-tier partials ride their job's
    // channels without disturbing the flat job's streams.
    let fleet =
        Fleet::connect(&fleet_clients(9), DriverKind::InProc, &Default::default()).unwrap();
    let sched = JobScheduler::new(fleet.clone(), 2, &results_dir());

    let mut tree = add_delta_job("sched_tree", 9, 2);
    tree.branching = 3;
    tree.min_clients = 3; // quorum in mid-tier nodes
    let (tid, tout) = submit_job(&sched, tree, 2, 400, 0.5, 10);

    let flat = add_delta_job("sched_tree_flat", 3, 2);
    let (fid, fout) = submit_job(&sched, flat, 2, 400, 2.0, 10);

    for (id, out, oracle) in [(tid, tout, 2.0f32), (fid, fout, 5.0f32)] {
        let outcome = sched.wait(id);
        assert_eq!(outcome.status, JobStatus::Completed, "{:?}", outcome.error);
        let (model_bytes, _hist) = out.lock().unwrap().take().unwrap();
        let model = fedflare::tensor::TensorDict::from_bytes(&model_bytes).unwrap();
        let v = model.get("key_000").unwrap().as_f32().unwrap();
        assert!(
            v.iter().all(|&x| (x - oracle).abs() < 1e-5),
            "expected {oracle}, got {}",
            v[0]
        );
    }
    sched.drain();
    fleet.shutdown();
}

#[test]
fn throttled_connection_is_shared_fairly_between_jobs() {
    // regression for the throttling-fairness satellite at the job level:
    // one client's whole connection at 8 MB/s; a job pushing a ~2 MB
    // model and a tiny job run concurrently. The tiny job must not wait
    // for the big job's full transfer (it only competes for budget), and
    // both finish correctly.
    let mut clients = fleet_clients(2);
    clients[1].bandwidth_bps = 8_000_000;
    let fleet = Fleet::connect(&clients, DriverKind::InProc, &Default::default()).unwrap();
    let sched = JobScheduler::new(fleet.clone(), 2, &results_dir());
    let mut big = add_delta_job("sched_thr_big", 2, 1);
    big.stream.chunk_bytes = 64 << 10;
    let (big_id, big_out) = submit_job(&sched, big, 2, 262_144, 0.5, 0);
    std::thread::sleep(Duration::from_millis(50)); // big job mid-transfer
    let t0 = Instant::now();
    let small_job = add_delta_job("sched_thr_small", 2, 1);
    let (small_id, small_out) = submit_job(&sched, small_job, 1, 64, 1.0, 0);
    let small = sched.wait(small_id);
    let small_wall = t0.elapsed();
    assert_eq!(small.status, JobStatus::Completed, "{:?}", small.error);
    let big_outcome = sched.wait(big_id);
    assert_eq!(big_outcome.status, JobStatus::Completed, "{:?}", big_outcome.error);
    // the big job's ~2 MB x 2 directions over a shared 8 MB/s link takes
    // ~500 ms; the small job (few kB) must finish well inside that
    assert!(
        small_wall < Duration::from_millis(450),
        "small job starved behind the big transfer: {small_wall:?}"
    );
    for out in [big_out, small_out] {
        assert!(out.lock().unwrap().is_some());
    }
    sched.drain();
    fleet.shutdown();
    let _ = std::fs::remove_dir_all(std::env::temp_dir().join("fedflare_scheduler_tests"));
}
