//! Streaming-aggregation acceptance tests: peak server-side gather memory
//! must be independent of client count (paper §2.4 / Fig-5 memory
//! accounting), while the aggregate matches an f64 oracle.
//!
//! These tests read the process-global gather counter
//! (`fedflare::util::mem::gather_*`), so every test that runs an FL job
//! serializes on [`JOBS`] — and they live in their own integration-test
//! binary so no other test's gathers pollute the counter.

use std::sync::Mutex;

use fedflare::config::{ClientSpec, JobConfig};
use fedflare::coordinator::{accept_registration, ClientHandle, Communicator, FedAvg};
use fedflare::executor::{ClientRuntime, Executor, StreamTestExecutor};
use fedflare::message::FlMessage;
use fedflare::sfm::inproc;
use fedflare::sim::{self, DriverKind};
use fedflare::streaming::Messenger;
use fedflare::util::mem;

static JOBS: Mutex<()> = Mutex::new(());

fn results_dir() -> String {
    let d = std::env::temp_dir().join("fedflare_streamagg");
    let _ = std::fs::create_dir_all(&d);
    d.to_string_lossy().to_string()
}

fn client_specs(n: usize) -> Vec<ClientSpec> {
    (0..n)
        .map(|i| ClientSpec {
            name: format!("site-{}", i + 1),
            bandwidth_bps: 0,
            partition: i,
        })
        .collect()
}

/// Run a stream_test FedAvg job with `n` clients and return the peak
/// gather bytes observed plus the final model for oracle checking.
fn run_fedavg(n: usize, keys: usize, key_elems: usize, rounds: usize, delta: f32) -> (u64, FedAvg) {
    let (peak, _report, ctl) = run_fedavg_topology(n, 0, keys, key_elems, rounds, delta);
    (peak, ctl)
}

/// Like [`run_fedavg`] but with a branching factor (0 = flat), also
/// returning the run report (per-node root gather peak).
fn run_fedavg_topology(
    n: usize,
    branching: usize,
    keys: usize,
    key_elems: usize,
    rounds: usize,
    delta: f32,
) -> (u64, sim::RunReport, FedAvg) {
    let mut job = JobConfig::named(&format!("sa_peak_{n}_{branching}"), "stream_test");
    job.rounds = rounds;
    job.branching = branching;
    job.clients = client_specs(n);
    // the root's children: mid-tier nodes in a tree, clients when flat
    let n_children = if branching > 1 && n > branching {
        n.div_ceil(branching)
    } else {
        n
    };
    job.min_clients = n_children;
    job.stream.chunk_bytes = 16 << 10;
    let initial = StreamTestExecutor::build_model(keys, key_elems, 1.0);
    let mut ctl = FedAvg::new(initial, rounds, n_children);
    ctl.task_name = "stream_test".into();
    let mut f: Box<sim::ExecutorFactory> = Box::new(move |_i, _s| {
        Ok(Box::new(StreamTestExecutor::new(None, delta)) as Box<dyn Executor>)
    });
    mem::reset_gather_peak();
    let report =
        sim::run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
    (mem::gather_peak(), report, ctl)
}

#[test]
fn gather_peak_is_flat_across_client_counts_and_tensor_sized() {
    let _lock = JOBS.lock().unwrap_or_else(|p| p.into_inner());
    let (keys, key_elems, rounds) = (4usize, 8192usize, 2usize);
    let result_bytes = (keys * key_elems * 4) as u64; // one client update
    let tensor_bytes = (key_elems * 4) as u64; // one tensor record
    let chunk = 16u64 << 10;

    let mut peaks = Vec::new();
    for &n in &[2usize, 4, 8, 16] {
        let (peak, ctl) = run_fedavg(n, keys, key_elems, rounds, 0.5);
        // oracle (f64): every client adds delta each round, weights equal,
        // so the aggregate is exactly initial + rounds * delta
        let oracle = 1.0f64 + rounds as f64 * 0.5f64;
        for (name, t) in ctl.model.iter() {
            let v = t.as_f32().expect("f32 model");
            assert!(
                v.iter().all(|&x| (x as f64 - oracle).abs() < 1e-5),
                "client count {n}: {name} diverged from oracle {oracle}: {}",
                v[0]
            );
        }
        peaks.push(peak);
    }

    // tensor-granular folding: at most STREAM_INFLIGHT(=2) workers hold
    // one decoded tensor record each while folding, so the peak is both
    // client-count independent AND tensor-sized — far below even a single
    // whole result, let alone O(clients x model)
    let lo = *peaks.iter().min().unwrap();
    let hi = *peaks.iter().max().unwrap();
    assert!(
        hi - lo <= tensor_bytes + chunk,
        "gather peak grew with client count: {peaks:?}"
    );
    for (i, &p) in peaks.iter().enumerate() {
        assert!(
            p >= tensor_bytes && p <= 2 * tensor_bytes + chunk,
            "peak #{i} = {p} outside [1, 2] tensor records \
             ({tensor_bytes}/record, {result_bytes}/result): {peaks:?}"
        );
    }
}

#[test]
fn hierarchical_512_clients_keep_root_gather_memory_flat() {
    // the scale-out acceptance: 512 clients aggregating through a 2-level
    // tree (--branching 16 => 32 mid-tier nodes) must complete FedAvg
    // with ROOT peak gather memory within 2x of a 16-client flat run.
    // Root fan-in is 32 partial streams instead of 512 client streams,
    // and the tensor-granular fold caps in-flight decoded records at
    // STREAM_INFLIGHT(=2) regardless of fan-in — so both peaks are a
    // couple of tensor records, not O(children x model).
    let _lock = JOBS.lock().unwrap_or_else(|p| p.into_inner());
    let (keys, key_elems, rounds, delta) = (2usize, 2048usize, 1usize, 0.5f32);
    let tensor_bytes = (key_elems * 4) as u64;

    let (_global_flat, flat_report, flat_ctl) =
        run_fedavg_topology(16, 0, keys, key_elems, rounds, delta);
    let (_global_tree, tree_report, tree_ctl) =
        run_fedavg_topology(512, 16, keys, key_elems, rounds, delta);

    // correctness first: every client adds delta with equal weight, so
    // both topologies land exactly on the oracle
    let oracle = 1.0f64 + rounds as f64 * delta as f64;
    for (ctl, label) in [(&flat_ctl, "flat-16"), (&tree_ctl, "tree-512/16")] {
        for (name, t) in ctl.model.iter() {
            let v = t.as_f32().expect("f32 model");
            assert!(
                v.iter().all(|&x| (x as f64 - oracle).abs() < 1e-5),
                "{label}: {name} diverged from oracle {oracle}: {}",
                v[0]
            );
        }
    }
    // the root of the tree gathered 32 partials, not 512 results
    assert_eq!(tree_ctl.history[0].per_client.len(), 32);
    assert!(tree_ctl.history[0].per_client.iter().all(|(n, ..)| n.starts_with("agg-")));

    // the acceptance bound: root peak within 2x of the 16-client flat run
    let (flat_peak, tree_peak) = (flat_report.root_gather_peak, tree_report.root_gather_peak);
    assert!(flat_peak > 0 && tree_peak > 0, "{flat_peak} {tree_peak}");
    assert!(
        tree_peak <= 2 * flat_peak,
        "512-client tree root peak {tree_peak} exceeds 2x the 16-client flat peak {flat_peak}"
    );
    // and in absolute terms both stay within the 2-in-flight-record cap
    for (peak, label) in [(flat_peak, "flat-16"), (tree_peak, "tree-512/16")] {
        assert!(
            peak <= 2 * tensor_bytes,
            "{label}: root peak {peak} above two tensor records ({tensor_bytes}/record)"
        );
    }
}

#[test]
fn server_staging_shrinks_with_tensor_count() {
    // acceptance: a fixed-size model split into K equal tensors — peak
    // decoded staging on the server shrinks ~1/K with tensor-granular
    // folding, while the aggregate stays equal to the batch path and the
    // f64 oracle
    let _lock = JOBS.lock().unwrap_or_else(|p| p.into_inner());
    let total_elems = 262_144usize; // 1 MB of f32 total, fixed
    let (n, rounds, delta) = (4usize, 1usize, 0.25f32);
    let mut peaks = Vec::new();
    for &k in &[1usize, 4, 16] {
        let key_elems = total_elems / k;
        let (peak, ctl) = run_fedavg(n, k, key_elems, rounds, delta);
        // f64 oracle: equal weights, every client adds delta each round
        let oracle = 1.0f64 + rounds as f64 * delta as f64;
        for (name, t) in ctl.model.iter() {
            let v = t.as_f32().expect("f32 model");
            assert!(
                v.iter().all(|&x| (x as f64 - oracle).abs() < 1e-5),
                "K={k}: {name} diverged from oracle {oracle}"
            );
        }
        // batch path over the same updates must agree with the streamed
        // tensor-granular aggregate
        let schema = StreamTestExecutor::build_model(k, key_elems, 0.0);
        let mut batch = fedflare::coordinator::StreamingMean::new(&schema);
        for c in 0..n {
            let body = StreamTestExecutor::build_model(k, key_elems, 1.0 + delta);
            let r = FlMessage::result("stream_test", 0, &format!("site-{}", c + 1), body);
            batch.fold(&r).unwrap();
        }
        let batch = batch.finish().unwrap();
        assert_eq!(ctl.history.len(), rounds);
        assert!(
            batch.max_abs_diff(&ctl.model) < 1e-5,
            "K={k}: batch path disagrees with streamed fold"
        );
        peaks.push(peak);
    }
    // peak staging ~ 2 x (model/K): demand at least a 1/2-per-4x shrink
    // with generous slack for the chunk-sized tail
    let chunk = (16u64 << 10) + 4096;
    assert!(
        peaks[1] + chunk < peaks[0],
        "K=4 did not shrink staging vs K=1: {peaks:?}"
    );
    assert!(
        peaks[2] + chunk < peaks[1],
        "K=16 did not shrink staging vs K=4: {peaks:?}"
    );
    assert!(
        peaks[2] * 4 < peaks[0],
        "K=16 should be far below K=1 ({peaks:?})"
    );
}

#[test]
fn legacy_wait_path_scales_with_client_count_streaming_does_not() {
    // broadcast_and_wait materializes every result before returning —
    // O(clients x model) on the server — while broadcast_and_reduce folds
    // and drops each result, holding at most two (flow gate). Measure
    // both against the same live cluster.
    let _lock = JOBS.lock().unwrap_or_else(|p| p.into_inner());
    let (k, keys, elems) = (6usize, 4usize, 8192usize);
    let result_bytes = (keys * elems * 4) as u64;

    let mut handles = Vec::new();
    let mut joins = Vec::new();
    for i in 0..k {
        let (sa, ca) = inproc::pair(64, &format!("peakdemo{i}"));
        let mut server_m = Messenger::new(Box::new(sa), 16 << 10, 0);
        let client_m = Messenger::new(Box::new(ca), 16 << 10, (i + 1) as u32);
        let name = format!("site-{}", i + 1);
        joins.push(std::thread::spawn(move || {
            let exec = Box::new(StreamTestExecutor::new(None, 0.5));
            let mut rt = ClientRuntime::new(&name, client_m, exec, vec![]);
            rt.run_loop().unwrap()
        }));
        let registered = accept_registration(&mut server_m).unwrap();
        handles.push(ClientHandle::spawn(registered, server_m));
    }
    let mut comm = Communicator::new(handles, 1);
    let all: Vec<usize> = (0..k).collect();
    let model = StreamTestExecutor::build_model(keys, elems, 1.0);

    mem::reset_gather_peak();
    let results = comm
        .broadcast_and_wait(&FlMessage::task("stream_test", 0, model.clone()), &all)
        .unwrap();
    let wait_peak = mem::gather_peak();
    assert_eq!(results.len(), k);
    drop(results);

    mem::reset_gather_peak();
    let folded = comm
        .broadcast_and_reduce(
            &FlMessage::task("stream_test", 1, model.clone()),
            &all,
            0usize,
            |n, _r| Ok(n + 1),
        )
        .unwrap();
    let reduce_peak = mem::gather_peak();
    assert_eq!(folded, k);
    comm.shutdown();
    drop(comm);
    for j in joins {
        j.join().unwrap();
    }

    assert!(
        wait_peak >= k as u64 * result_bytes,
        "wait path should hold all {k} results: peak {wait_peak} vs {result_bytes}/result"
    );
    assert!(
        reduce_peak >= result_bytes && reduce_peak <= 2 * result_bytes,
        "streaming fold should hold at most 2 results (flow gate): \
         peak {reduce_peak} vs {result_bytes}/result"
    );
}

#[test]
fn completion_order_equals_target_order_result() {
    // throttle one client so completion order inverts dispatch order; the
    // aggregate must match the unthrottled run within float tolerance
    let _lock = JOBS.lock().unwrap_or_else(|p| p.into_inner());
    let run = |throttle_first: bool| {
        let mut job = JobConfig::named("sa_order", "stream_test");
        job.rounds = 1;
        job.min_clients = 2;
        job.stream.chunk_bytes = 32 << 10;
        if throttle_first {
            // 1 MB burst covers ~half the 2 MB model; the rest crawls
            job.clients[0].bandwidth_bps = 12_000_000;
        }
        let initial = StreamTestExecutor::build_model(2, 262_144, 1.0);
        let mut ctl = FedAvg::new(initial, 1, 2);
        ctl.task_name = "stream_test".into();
        let mut f: Box<sim::ExecutorFactory> = Box::new(|i, _s| {
            // distinct deltas so ordering mistakes change the mean
            Ok(Box::new(StreamTestExecutor::new(None, 0.1 * (i + 1) as f32))
                as Box<dyn Executor>)
        });
        sim::run_job(&job, DriverKind::InProc, &mut ctl, &mut f, &results_dir()).unwrap();
        ctl.model
    };
    let plain = run(false);
    let inverted = run(true);
    assert!(
        plain.max_abs_diff(&inverted) < 1e-5,
        "completion order changed the aggregate: {}",
        plain.max_abs_diff(&inverted)
    );
}
