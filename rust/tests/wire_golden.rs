//! Golden wire-format tests: fixed, checked-in byte fixtures for the v1
//! `TensorDict` blob format and the v2 per-tensor records, so any silent
//! format drift (field reorder, width change, endianness, length
//! semantics) fails loudly instead of corrupting cross-version jobs.
//!
//! The fixtures are hex literals generated once from the format spec
//! (little-endian throughout):
//!
//! ```text
//! v1 blob:   u32 count | per tensor: str name, u8 dtype, u8 ndim,
//!            u32 dims.., u32 elem_count, payload
//! v2 record: str name | u8 dtype | u8 enc | u8 ndim | u32 dims..
//!            | u32 byte_len | payload
//! ```

use fedflare::message::FlMessage;
use fedflare::tensor::{decode_record, encode_record, RecordEnc, Tensor, TensorDict};

/// The fixture dict: one f32 vector, one i32 vector, one f32 matrix —
/// names chosen so sorted iteration order is (a.bias, ids, w).
fn fixture_dict() -> TensorDict {
    let mut d = TensorDict::new();
    d.insert("a.bias", Tensor::f32(vec![3], vec![-1.0, 0.0, 1.5]));
    d.insert("ids", Tensor::i32(vec![2], vec![7, -9]));
    d.insert("w", Tensor::f32(vec![2, 2], vec![0.5, -2.0, 3.25, 100.0]));
    d
}

fn unhex(s: &str) -> Vec<u8> {
    assert!(s.len() % 2 == 0);
    (0..s.len())
        .step_by(2)
        .map(|i| u8::from_str_radix(&s[i..i + 2], 16).unwrap())
        .collect()
}

/// v1 blob encoding of [`fixture_dict`] — byte-exact.
const V1_BLOB: &str = "0300000006000000612e6269617300010300000003000000000080bf000000000000c03f030000006964730101020000000200000007000000f7ffffff010000007700020200000002000000040000000000003f000000c0000050400000c842";

/// v2 raw records of the same tensors, one per tensor, name order.
const V2_A_BIAS: &str =
    "06000000612e62696173000001030000000c000000000080bf000000000000c03f";
const V2_IDS: &str = "03000000696473010001020000000800000007000000f7ffffff";
const V2_W: &str =
    "01000000770000020200000002000000100000000000003f000000c0000050400000c842";

/// v2 f16-encoded record of tensor `w` (payload halves to 2 bytes/elem).
const V2_W_F16: &str = "0100000077000102020000000200000008000000003800c080424056";

/// v2 int8-encoded record of tensor `w`: an 8-byte `f32 scale | f32 min`
/// prefix (scale = 102/255, min = -2.0) then one code byte per element.
const V2_W_INT8: &str =
    "010000007700020202000000020000000c000000cdcccc3e000000c006000dff";

/// v2 int4-encoded record of tensor `w`: the same prefix (scale =
/// 102/15) then two codes per byte, low nibble first.
const V2_W_INT4: &str = "010000007700030202000000020000000a0000009a99d940000000c000f1";

#[test]
fn v1_blob_bytes_are_stable() {
    let d = fixture_dict();
    assert_eq!(
        d.to_bytes(),
        unhex(V1_BLOB),
        "v1 TensorDict wire format drifted"
    );
    // and the checked-in bytes still decode to the same dict
    assert_eq!(TensorDict::from_bytes(&unhex(V1_BLOB)).unwrap(), d);
}

#[test]
fn v2_record_bytes_are_stable() {
    let d = fixture_dict();
    for (name, fix) in [("a.bias", V2_A_BIAS), ("ids", V2_IDS), ("w", V2_W)] {
        let t = d.get(name).unwrap();
        assert_eq!(
            encode_record(name, t, RecordEnc::Raw),
            unhex(fix),
            "v2 record format drifted for {name}"
        );
        let (n2, t2) = decode_record(&unhex(fix)).unwrap();
        assert_eq!(n2, name);
        assert_eq!(&t2, t);
    }
}

#[test]
fn v2_f16_record_bytes_are_stable() {
    let d = fixture_dict();
    let t = d.get("w").unwrap();
    assert_eq!(
        encode_record("w", t, RecordEnc::F16),
        unhex(V2_W_F16),
        "v2 f16 record format drifted"
    );
    // the fixture's values are exactly f16-representable, so decoding
    // recovers them losslessly
    let (n2, t2) = decode_record(&unhex(V2_W_F16)).unwrap();
    assert_eq!(n2, "w");
    assert_eq!(&t2, t);
}

#[test]
fn v2_int8_record_bytes_are_stable() {
    let d = fixture_dict();
    let t = d.get("w").unwrap();
    assert_eq!(
        encode_record("w", t, RecordEnc::Int8),
        unhex(V2_W_INT8),
        "v2 int8 record format drifted"
    );
    // decoding dequantizes; every element lands within scale/2 of the
    // original (scale = (100 - (-2)) / 255 = 0.4)
    let (n2, t2) = decode_record(&unhex(V2_W_INT8)).unwrap();
    assert_eq!(n2, "w");
    assert_eq!(t2.shape, t.shape);
    let (orig, deq) = (t.as_f32().unwrap(), t2.as_f32().unwrap());
    for (a, b) in orig.iter().zip(deq) {
        assert!((a - b).abs() <= 0.4 / 2.0 + 1e-6, "int8 |{a} - {b}| > scale/2");
    }
    // the range endpoints are code 0 and code 255: they decode exactly
    assert_eq!(deq[1], -2.0);
    assert_eq!(deq[3], 100.0);
}

#[test]
fn v2_int4_record_bytes_are_stable() {
    let d = fixture_dict();
    let t = d.get("w").unwrap();
    assert_eq!(
        encode_record("w", t, RecordEnc::Int4),
        unhex(V2_W_INT4),
        "v2 int4 record format drifted"
    );
    let (n2, t2) = decode_record(&unhex(V2_W_INT4)).unwrap();
    assert_eq!(n2, "w");
    assert_eq!(t2.shape, t.shape);
    let (orig, deq) = (t.as_f32().unwrap(), t2.as_f32().unwrap());
    for (a, b) in orig.iter().zip(deq) {
        assert!((a - b).abs() <= 6.8 / 2.0 + 1e-5, "int4 |{a} - {b}| > scale/2");
    }
    assert_eq!(deq[1], -2.0);
    assert_eq!(deq[3], 100.0);
}

#[test]
fn int8_int4_roundtrip_error_is_bounded_property() {
    // random f32 tensors: quantize -> dequantize error stays within the
    // documented scale/2 bound for both code widths (mirrors the f16
    // lossless-fixture test, at the codecs' coarser precision)
    fedflare::util::prop::check("int8/int4 error bound", 80, |g| {
        let data = g.f32s(1, 200);
        let t = Tensor::f32(vec![data.len()], data.clone());
        let (lo, hi) = data
            .iter()
            .fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &x| {
                (l.min(x), h.max(x))
            });
        let range = (hi - lo).max(0.0) as f64;
        for (enc, levels) in [(RecordEnc::Int8, 255.0), (RecordEnc::Int4, 15.0)] {
            let rec = encode_record("t", &t, enc);
            let (_, back) = decode_record(&rec).map_err(|e| e.to_string())?;
            let deq = back.as_f32().unwrap();
            // scale/2 plus f32 rounding headroom on the affine arithmetic
            let bound = range / levels / 2.0 + 1e-4 * range + 1e-6;
            for (a, b) in data.iter().zip(deq) {
                fedflare::util::prop::assert_that(
                    ((*a as f64) - (*b as f64)).abs() <= bound,
                    &format!("{} error |{a} - {b}| exceeds {bound}", enc.as_str()),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn quantized_payloads_shrink_on_the_wire() {
    // 1000 elements: raw 4 B/elem, int8 ~1 B/elem, int4 ~0.5 B/elem
    // (plus the fixed 8-byte scale/min prefix and record header)
    let t = Tensor::f32(vec![1000], (0..1000).map(|i| i as f32).collect());
    let raw = encode_record("t", &t, RecordEnc::Raw).len();
    let q8 = encode_record("t", &t, RecordEnc::Int8).len();
    let q4 = encode_record("t", &t, RecordEnc::Int4).len();
    assert!(q8 * 3 < raw, "int8 not ~4x smaller: {q8} vs {raw}");
    assert!(q4 * 6 < raw, "int4 not ~8x smaller: {q4} vs {raw}");
}

#[test]
fn frame_iter_stages_one_record_not_the_payload() {
    // a message with several large tensors: the lazy v2 frame encoder's
    // tracked bytes must stay near one record (1 MB here), far below the
    // full 8 MB encoded payload. This test lives in its own test binary
    // (own process) so the process-global tracked-bytes counter is not
    // raced by the lib tests' streaming.
    use fedflare::message::FrameIter;
    use fedflare::util::mem;

    let elems = (1 << 20) / 4; // 1 MB per tensor
    let mut body = TensorDict::new();
    for i in 0..8 {
        body.insert(format!("t{i}"), Tensor::f32(vec![elems], vec![0.5; elems]));
    }
    let m = FlMessage::task("train", 0, body);
    let full = m.v2_encoded_len(RecordEnc::Raw);
    let before = mem::tracked_bytes();
    let mut peak = 0i64;
    let mut frames = 0usize;
    for f in FrameIter::new(&m, 4, 1, 64 << 10, RecordEnc::Raw) {
        peak = peak.max(mem::tracked_bytes() - before);
        frames += 1;
        std::hint::black_box(f.payload.len());
    }
    assert_eq!(mem::tracked_bytes(), before, "encoder leaked tracking");
    assert_eq!(frames as u32, full.div_ceil(64 << 10) as u32);
    // one record (1 MB + chunk) vs the 8 MB payload: demand < 1/4
    assert!(
        peak < (full / 4) as i64,
        "lazy encoder staged {peak} of {full} bytes"
    );
}

#[test]
fn v1_v2_roundtrip_equivalence_property() {
    // random messages: decoding the v1 blob and the v2 record stream must
    // yield identical messages (the compat guarantee that lets old and
    // new peers interoperate)
    fedflare::util::prop::check("golden v1<->v2 equivalence", 60, |g| {
        let mut body = TensorDict::new();
        for i in 0..g.usize_in(0, 6) {
            let data = g.f32s(0, 120);
            body.insert(format!("t{i}"), Tensor::f32(vec![data.len()], data));
        }
        let m = FlMessage::result(&g.ident(), g.usize_in(0, 99), &g.ident(), body);
        let v1 = FlMessage::from_bytes(&m.to_bytes()).map_err(|e| e.to_string())?;
        let v2 = FlMessage::from_v2_bytes(&m.to_v2_bytes(RecordEnc::Raw))
            .map_err(|e| e.to_string())?;
        fedflare::util::prop::assert_that(v1 == m && v2 == m, "wire formats disagree")
    });
}
