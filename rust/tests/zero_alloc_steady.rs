//! Zero-allocation steady-state regression — the tentpole's acceptance
//! gate. After a warmup round populates the buffer pool's size classes,
//! a multi-round object exchange must run the whole codec → frame →
//! driver path without a single pool miss or unpooled payload wrap:
//! [`fedflare::util::mem::pool_misses`] and
//! [`fedflare::util::mem::frame_allocs`] stay flat while
//! [`fedflare::util::mem::pool_hits`] keeps climbing.
//!
//! This test lives alone in its own binary on purpose: the counters are
//! process-global, and a sibling test sending control frames (unpooled
//! `Vec<u8>` payload wraps are *counted*, by design) would make the
//! zero-delta assertion flaky.

use fedflare::message::FlMessage;
use fedflare::sfm::inproc;
use fedflare::streaming::Messenger;
use fedflare::tensor::{Tensor, TensorDict};
use fedflare::util::mem;

#[test]
fn steady_state_rounds_allocate_nothing_on_the_frame_path() {
    // 4 x 64 KiB tensors over 16 KiB chunks: every pooled size class the
    // path touches (header record, tensor records, boundary staging) is
    // exercised each round, and records span multiple chunks so both the
    // zero-copy slice branch and the staging branch run.
    let mut body = TensorDict::new();
    for i in 0..4 {
        body.insert(
            format!("layer{i}"),
            Tensor::f32(vec![16_384], vec![0.5; 16_384]),
        );
    }
    let msg = FlMessage::task("train", 0, body);

    let (a, b) = inproc::pair(256, "zero-alloc");
    let mut tx = Messenger::new(Box::new(a), 16 << 10, 1);
    let mut rx = Messenger::new(Box::new(b), 16 << 10, 2);

    let mut round = |tx: &mut Messenger, rx: &mut Messenger| {
        tx.send_msg(&msg).expect("send round");
        let got = rx.recv_msg().expect("recv round");
        assert_eq!(got.body.len(), 4);
    };

    // warmup: cold size classes miss once while the pool fills
    for _ in 0..2 {
        round(&mut tx, &mut rx);
    }

    let misses0 = mem::pool_misses();
    let allocs0 = mem::frame_allocs();
    let hits0 = mem::pool_hits();

    for _ in 0..5 {
        round(&mut tx, &mut rx);
    }

    assert_eq!(
        mem::pool_misses() - misses0,
        0,
        "pool missed after warmup: the hot path allocated"
    );
    assert_eq!(
        mem::frame_allocs() - allocs0,
        0,
        "a frame payload was heap-allocated outside the pool after warmup"
    );
    // guard against vacuous success: the rounds really did go through the
    // pool (a rewrite that bypasses `pool::take` entirely would keep the
    // miss counter flat too)
    assert!(
        mem::pool_hits() > hits0,
        "no pool checkouts at all — the data plane stopped using the pool"
    );
}
