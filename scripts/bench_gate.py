#!/usr/bin/env python3
"""Perf trend gate: diff fresh bench JSON against the committed baseline.

Usage:
    scripts/bench_gate.py BASELINE.json FRESH.json [--threshold 0.25]

Compares every ``wall_s*`` field of every row (rows matched by their
identity fields: k / clients / branching / connections / churn_batch /
model_mb / case / op / storm) and fails — exit 1 — when any wall-clock number regressed by more than
the threshold (default 25%). Non-wall-clock fields (peak bytes, thread
counts) are reported but never gate: they are tracked via the uploaded
artifacts instead.

Baselines marked ``"provisional": true`` never fail the gate: they were
committed without a measured run (e.g. authored on a machine without
the toolchain) — the gate prints the comparison, asks for the baseline
to be refreshed from a real run, and exits 0. To refresh::

    FEDFLARE_BENCH_QUICK=1 cargo bench --bench bench_jobs --bench bench_topology \
        --bench bench_fleet --bench bench_streaming
    cp rust/BENCH_jobs.json bench/baseline/BENCH_jobs.json   # drop "provisional"

Quick-mode output must be compared against a quick-mode baseline (and
full against full); mismatched modes are skipped with a warning, since
the workloads differ by design.
"""

import json
import sys

ID_KEYS = (
    "k",
    "clients",
    "branching",
    "connections",
    "churn_batch",
    "model_mb",
    "case",
    "op",
    "storm",
    "exporter",
)


def identity(row):
    return tuple((k, row[k]) for k in ID_KEYS if k in row)


def rows_of(doc):
    out = {}
    for key, val in doc.items():
        if isinstance(val, list) and all(isinstance(r, dict) for r in val):
            for row in val:
                out[(key,) + identity(row)] = row
    return out


def main(argv):
    args = [a for a in argv[1:] if not a.startswith("--")]
    threshold = 0.25
    for a in argv[1:]:
        if a.startswith("--threshold"):
            threshold = float(a.split("=", 1)[1]) if "=" in a else threshold
    if len(args) != 2:
        print(__doc__)
        return 2
    base_path, fresh_path = args
    with open(base_path) as f:
        base = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)

    provisional = bool(base.get("provisional"))
    if base.get("quick") != fresh.get("quick"):
        print(
            f"bench_gate: SKIP {fresh_path}: quick={fresh.get('quick')} vs "
            f"baseline quick={base.get('quick')} — refresh the baseline in the same mode"
        )
        return 0

    base_rows, fresh_rows = rows_of(base), rows_of(fresh)
    regressions, compared = [], 0
    for key, brow in sorted(base_rows.items()):
        frow = fresh_rows.get(key)
        if frow is None:
            print(f"bench_gate: warn: baseline row {key} missing from fresh output")
            continue
        for field, bval in brow.items():
            if not field.startswith("wall_s") or not isinstance(bval, (int, float)):
                continue
            fval = frow.get(field)
            if not isinstance(fval, (int, float)):
                continue
            if bval < 0.05:  # below measurement noise; don't gate on it
                continue
            compared += 1
            ratio = fval / bval
            marker = "REGRESSION" if ratio > 1 + threshold else "ok"
            print(f"  {key} {field}: {bval:.3f}s -> {fval:.3f}s ({ratio - 1:+.0%}) {marker}")
            if ratio > 1 + threshold:
                regressions.append((key, field, bval, fval))

    if not compared:
        print(f"bench_gate: warn: no comparable wall_s fields between {base_path} and {fresh_path}")
    if regressions:
        if provisional:
            print(
                f"bench_gate: {len(regressions)} wall-clock regression(s) vs a PROVISIONAL "
                "baseline — not failing. Refresh bench/baseline/ from a measured run "
                "and drop the provisional flag to arm the gate."
            )
            return 0
        print(f"bench_gate: FAIL — {len(regressions)} wall-clock regression(s) > {threshold:.0%}:")
        for key, field, bval, fval in regressions:
            print(f"  {key} {field}: {bval:.3f}s -> {fval:.3f}s")
        return 1
    note = " (baseline provisional — refresh it from a measured run)" if provisional else ""
    print(f"bench_gate: PASS — {compared} wall-clock fields within {threshold:.0%}{note}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
