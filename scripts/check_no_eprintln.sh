#!/usr/bin/env sh
# CI lint gate for the observability plane: the library core
# (rust/src/sfm/, rust/src/coordinator/, rust/src/fleet/) must not write
# ad-hoc diagnostics to stdout/stderr. Library diagnostics go through
# `obs::log!` (leveled, `FEDFLARE_LOG`-gated, counted per level in the
# metrics registry) so operators control verbosity with one knob and the
# `log.lines{level=...}` counters stay truthful; an `eprintln!` or
# `println!` creeping back in bypasses both. The CLI layer (main.rs,
# repro/) prints user-facing output freely — it is not linted.
#
# A deliberate, reviewed print site can be sanctioned by putting the
# marker comment `loglint-allow: <reason>` on the line directly above
# it. Test modules are exempt: everything after the first `#[cfg(test)]`
# in a file is ignored.
set -eu

root="$(cd "$(dirname "$0")/.." && pwd)"
status=0

for f in $(find "$root/rust/src/sfm" "$root/rust/src/coordinator" "$root/rust/src/fleet" -name '*.rs' | sort); do
    hits="$(awk '
        /#\[cfg\(test\)\]/ { intest = 1 }
        intest { next }
        /eprintln!|println!/ {
            if (prev !~ /loglint-allow:/) {
                printf "%s:%d: %s\n", FILENAME, FNR, $0
            }
        }
        { prev = $0 }
    ' "$f")"
    if [ -n "$hits" ]; then
        echo "$hits"
        status=1
    fi
done

if [ "$status" -ne 0 ]; then
    echo ""
    echo "error: ad-hoc stdout/stderr diagnostics in the library core." >&2
    echo "Library code under sfm/, coordinator/ and fleet/ logs through" >&2
    echo "obs::log!(level, ...) — leveled, FEDFLARE_LOG-gated, and counted" >&2
    echo "in the metrics registry (see rust/README.md, Observability). If" >&2
    echo "the print is deliberate, mark the preceding line with" >&2
    echo "'loglint-allow: <reason>'." >&2
    exit 1
fi
echo "log lint: library core logs through obs::log! only (ok)"
