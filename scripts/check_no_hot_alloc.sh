#!/usr/bin/env sh
# CI lint gate for the zero-copy data plane: the frame hot path in
# rust/src/sfm/reactor.rs and rust/src/sfm/mux.rs must not allocate
# per-frame byte buffers. Payloads come from the buffer pool
# (rust/src/util/pool.rs: `pool::take` + `PoolBuf::freeze`) and travel as
# shared `Payload` slices; a `.to_vec()`, `vec![..]`, or
# `Vec::with_capacity(..)` creeping back into those files reintroduces
# the copy-per-hop design this codebase moved away from and silently
# breaks the steady-state zero-allocation regression test
# (rust/tests/zero_alloc_steady.rs).
#
# A deliberate, reviewed allocation site can be sanctioned by putting the
# marker comment `alloclint-allow: <reason>` on the line directly above
# it. Test modules are exempt: everything after the first `#[cfg(test)]`
# in a file is ignored (tests build fixture buffers freely).
set -eu

root="$(cd "$(dirname "$0")/.." && pwd)"
status=0

for f in "$root/rust/src/sfm/reactor.rs" "$root/rust/src/sfm/mux.rs"; do
    hits="$(awk '
        /#\[cfg\(test\)\]/ { intest = 1 }
        intest { next }
        /\.to_vec\(|vec!\[|Vec::with_capacity\(/ {
            if (prev !~ /alloclint-allow:/) {
                printf "%s:%d: %s\n", FILENAME, FNR, $0
            }
        }
        { prev = $0 }
    ' "$f")"
    if [ -n "$hits" ]; then
        echo "$hits"
        status=1
    fi
done

if [ "$status" -ne 0 ]; then
    echo ""
    echo "error: per-frame buffer allocation on the data-plane hot path." >&2
    echo "Frame payloads in sfm/reactor.rs and sfm/mux.rs must come from the" >&2
    echo "buffer pool (util/pool.rs) or ride as shared Payload slices — see" >&2
    echo "rust/README.md, buffer lifecycle. If the allocation is deliberate," >&2
    echo "mark the preceding line with 'alloclint-allow: <reason>'." >&2
    exit 1
fi
echo "hot-alloc lint: data-plane hot path allocates through the pool only (ok)"
