#!/usr/bin/env sh
# CI lint gate for the event-driven connection core: the reactor owns
# every thread under rust/src/sfm/ and rust/src/fleet/. The ONLY place
# allowed to spawn is the reactor's shard pool — one `thread::Builder`
# call in rust/src/sfm/reactor.rs whose preceding line carries the
# marker comment `threadlint-allow: shard-pool`. Any other
# `thread::spawn` / `thread::Builder` in those trees (reactor.rs
# included) is a regression to the thread-per-connection design this
# codebase moved away from — per-connection work belongs on a reactor
# shard's poll loop or timer wheel, not on a new thread.
#
# Test modules are exempt: everything after the first `#[cfg(test)]` in
# a file is ignored (tests spawn threads to act as peers).
set -eu

root="$(cd "$(dirname "$0")/.." && pwd)"
status=0
marked=0

for f in $(find "$root/rust/src/sfm" "$root/rust/src/fleet" -name '*.rs' | sort); do
    hits="$(awk '
        /#\[cfg\(test\)\]/ { intest = 1 }
        intest { next }
        /thread::spawn|thread::Builder/ {
            if (prev ~ /threadlint-allow: shard-pool/) {
                printf "MARKED %s:%d\n", FILENAME, FNR
            } else {
                printf "%s:%d: %s\n", FILENAME, FNR, $0
            }
        }
        { prev = $0 }
    ' "$f")"
    if [ -n "$hits" ]; then
        # count + strip the sanctioned shard-pool site, report the rest
        n="$(printf '%s\n' "$hits" | grep -c '^MARKED ' || true)"
        marked=$((marked + n))
        bad="$(printf '%s\n' "$hits" | grep -v '^MARKED ' || true)"
        if [ -n "$bad" ]; then
            echo "$bad"
            status=1
        fi
    fi
done

# the marker may only sanction the reactor's shard pool, exactly once
if [ "$marked" -ne 1 ]; then
    echo "error: expected exactly one 'threadlint-allow: shard-pool' spawn site" >&2
    echo "in rust/src/sfm/reactor.rs, found $marked." >&2
    status=1
elif ! grep -q 'threadlint-allow: shard-pool' "$root/rust/src/sfm/reactor.rs"; then
    echo "error: the shard-pool marker is not in rust/src/sfm/reactor.rs." >&2
    status=1
fi

if [ "$status" -ne 0 ]; then
    echo ""
    echo "error: thread spawn outside the reactor shard pool in the connection core." >&2
    echo "Per-connection receive/timer work must run on an sfm reactor shard" >&2
    echo "(rust/src/sfm/reactor.rs) — see rust/README.md, thread budget." >&2
    exit 1
fi
echo "thread-spawn lint: connection core spawns only the reactor shard pool (ok)"
