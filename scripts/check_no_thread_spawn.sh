#!/usr/bin/env sh
# CI lint gate for the event-driven connection core: the reactor owns
# every thread under rust/src/sfm/ and rust/src/fleet/. Any other
# `thread::spawn` / `thread::Builder` in those trees is a regression to
# the thread-per-connection design this codebase moved away from —
# per-connection work belongs on the reactor's poll loop or timer wheel
# (rust/src/sfm/reactor.rs), not on a new thread.
#
# Test modules are exempt: everything after the first `#[cfg(test)]` in
# a file is ignored (tests spawn threads to act as peers).
set -eu

root="$(cd "$(dirname "$0")/.." && pwd)"
status=0

for f in $(find "$root/rust/src/sfm" "$root/rust/src/fleet" -name '*.rs' ! -name 'reactor.rs' | sort); do
    hits="$(awk '
        /#\[cfg\(test\)\]/ { intest = 1 }
        intest { next }
        /thread::spawn|thread::Builder/ { printf "%s:%d: %s\n", FILENAME, FNR, $0 }
    ' "$f")"
    if [ -n "$hits" ]; then
        echo "$hits"
        status=1
    fi
done

if [ "$status" -ne 0 ]; then
    echo ""
    echo "error: thread spawn outside the reactor in the connection core." >&2
    echo "Per-connection receive/timer work must run on the sfm reactor" >&2
    echo "(rust/src/sfm/reactor.rs) — see rust/README.md, thread budget." >&2
    exit 1
fi
echo "thread-spawn lint: connection core is reactor-only (ok)"
